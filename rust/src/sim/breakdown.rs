//! Cycle accounting by category — the columns of the paper's Table 2.

use std::ops::{Add, AddAssign};

/// Cycles attributed to each activity of the GEMM execution. `total` is
/// tracked separately from the sum of the parts because the AIE tile
/// overlaps compute with Ar streaming (the whole point of §5.3): the
/// category columns answer "how long would this take alone", `total`
/// answers "how long did the schedule take".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CycleBreakdown {
    /// Cycles streaming Ar vectors from Ultra RAM (category time).
    pub ar_stream: u64,
    /// Cycles executing mac16 arithmetic + loop control (category time).
    pub arithmetic: u64,
    /// Cycles copying Br micro-panels BRAM → local memory.
    pub br_copy: u64,
    /// Cycles in GMIO round trips for Cr (load + store, incl. contention).
    pub copy_cr: u64,
    /// Cycles in packing Ac/Bc into the FPGA RAMs (amortised; §4.5 says
    /// negligible for large problems — tracked so we can *show* that).
    pub packing: u64,
    /// Leader orchestration / synchronisation cycles.
    pub orchestration: u64,
    /// Wall-clock cycles of the schedule (with overlap).
    pub total: u64,
}

impl CycleBreakdown {
    pub fn zero() -> Self {
        Self::default()
    }

    /// Sum of category times — an upper bound on `total` when nothing
    /// overlaps; the gap `serial_sum() - total` measures overlap won.
    pub fn serial_sum(&self) -> u64 {
        self.ar_stream
            + self.arithmetic
            + self.br_copy
            + self.copy_cr
            + self.packing
            + self.orchestration
    }

    /// MACs/cycle given a MAC count, using wall-clock cycles.
    pub fn macs_per_cycle(&self, macs: u64) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            macs as f64 / self.total as f64
        }
    }
}

impl Add for CycleBreakdown {
    type Output = CycleBreakdown;
    fn add(self, o: CycleBreakdown) -> CycleBreakdown {
        CycleBreakdown {
            ar_stream: self.ar_stream + o.ar_stream,
            arithmetic: self.arithmetic + o.arithmetic,
            br_copy: self.br_copy + o.br_copy,
            copy_cr: self.copy_cr + o.copy_cr,
            packing: self.packing + o.packing,
            orchestration: self.orchestration + o.orchestration,
            total: self.total + o.total,
        }
    }
}

impl AddAssign for CycleBreakdown {
    fn add_assign(&mut self, o: CycleBreakdown) {
        *self = *self + o;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_accumulates_fields() {
        let a = CycleBreakdown { ar_stream: 1, arithmetic: 2, br_copy: 3, copy_cr: 4, packing: 5, orchestration: 6, total: 7 };
        let b = a + a;
        assert_eq!(b.ar_stream, 2);
        assert_eq!(b.total, 14);
        assert_eq!(b.serial_sum(), 2 * (1 + 2 + 3 + 4 + 5 + 6));
    }

    #[test]
    fn macs_per_cycle_handles_zero() {
        assert_eq!(CycleBreakdown::zero().macs_per_cycle(100), 0.0);
        let c = CycleBreakdown { total: 50, ..Default::default() };
        assert!((c.macs_per_cycle(100) - 2.0).abs() < 1e-12);
    }
}
