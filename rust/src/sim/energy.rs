//! Energy model for the simulated GEMM execution (extension).
//!
//! The paper evaluates cycles only; energy is the natural companion
//! metric for an embedded ACAP and follows the same breakdown: each
//! [`CycleBreakdown`] category maps to data movement at a memory level
//! (with a per-byte cost) or to arithmetic (per-MAC cost). Coefficients
//! are order-of-magnitude figures for a 7 nm SoC (pJ scale), configurable
//! for sensitivity studies; tests pin the *structure* (movement from DDR
//! dominates per byte, arithmetic per MAC is cheapest), not the absolute
//! joules.

use super::breakdown::CycleBreakdown;

/// Energy coefficients in picojoules.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// pJ per UINT8 MAC in the AIE vector unit.
    pub pj_per_mac: f64,
    /// pJ per byte moved from DDR (GMIO traffic: Cr, packing).
    pub pj_per_byte_ddr: f64,
    /// pJ per byte streamed from the FPGA RAMs (Ar, Bc→Br).
    pub pj_per_byte_fpga: f64,
    /// pJ per byte read from tile local memory (Br inside the kernel).
    pub pj_per_byte_local: f64,
    /// Static/leakage power per active tile, pJ per cycle.
    pub pj_static_per_tile_cycle: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // 7 nm-class figures (order of magnitude): int8 MAC ≈ 0.05 pJ,
        // on-chip SRAM ≈ 1–2 pJ/B, off-chip DDR4 ≈ 20 pJ/B.
        EnergyModel {
            pj_per_mac: 0.05,
            pj_per_byte_ddr: 20.0,
            pj_per_byte_fpga: 2.0,
            pj_per_byte_local: 1.0,
            pj_static_per_tile_cycle: 5.0,
        }
    }
}

/// Itemised energy of a GEMM execution, in picojoules.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EnergyBreakdown {
    pub arithmetic_pj: f64,
    pub ddr_pj: f64,
    pub fpga_pj: f64,
    pub local_pj: f64,
    pub static_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.arithmetic_pj + self.ddr_pj + self.fpga_pj + self.local_pj + self.static_pj
    }

    /// Energy efficiency in MACs per nanojoule.
    pub fn macs_per_nj(&self, macs: u64) -> f64 {
        macs as f64 / (self.total_pj() / 1e3)
    }
}

/// Traffic volumes of a GEMM run (bytes per category), derivable from the
/// problem shape and the schedule.
#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    pub macs: u64,
    /// Bytes over GMIO/DDR: Cr loads+stores and (if counted) packing.
    pub ddr_bytes: u64,
    /// Bytes streamed out of the FPGA RAMs: Ar multicast + Br copies.
    pub fpga_bytes: u64,
    /// Bytes read from local memory inside the kernel (Br reads).
    pub local_bytes: u64,
}

impl Traffic {
    /// Traffic of the paper's blocked GEMM on one (mc, nc, kc) block
    /// with `tiles` AIE tiles (Figure 6's data-movement scheme).
    pub fn for_block(mc: usize, nc: usize, kc: usize, tiles: usize) -> Traffic {
        let panels_a = (mc / 8) as u64;
        let panels_b = (nc / 8) as u64;
        let kernels = panels_a * panels_b;
        let kc = kc as u64;
        Traffic {
            macs: kernels * 64 * kc,
            // Cr: 8×8 u8 load + 8×8 i16 store per kernel (Figure 4).
            ddr_bytes: kernels * (64 + 128),
            // Ar streamed once per kernel (multicast replicates on-chip,
            // the FPGA port is read once per multicast group — divide by
            // the group size, conservatively the active tile count).
            fpga_bytes: kernels * 8 * kc / (tiles as u64).max(1)
                + panels_b * kc * 8, // Br copies BRAM → local
            local_bytes: kernels * 8 * kc, // Br read per kernel
        }
    }
}

/// Price a run: cycles (for static energy) + traffic (for dynamic).
pub fn energy_of(model: &EnergyModel, cycles: &CycleBreakdown, traffic: &Traffic, tiles: usize) -> EnergyBreakdown {
    EnergyBreakdown {
        arithmetic_pj: traffic.macs as f64 * model.pj_per_mac,
        ddr_pj: traffic.ddr_bytes as f64 * model.pj_per_byte_ddr,
        fpga_pj: traffic.fpga_bytes as f64 * model.pj_per_byte_fpga,
        local_pj: traffic.local_bytes as f64 * model.pj_per_byte_local,
        static_pj: cycles.total as f64 * tiles as f64 * model.pj_static_per_tile_cycle,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn block_energy(tiles: usize) -> (EnergyBreakdown, u64) {
        let t = Traffic::for_block(256, 256, 2048, tiles);
        let cycles = CycleBreakdown { total: 3_700_000 / tiles as u64, ..Default::default() };
        (energy_of(&EnergyModel::default(), &cycles, &t, tiles), t.macs)
    }

    #[test]
    fn totals_are_positive_and_itemised() {
        let (e, macs) = block_energy(8);
        assert!(e.arithmetic_pj > 0.0 && e.ddr_pj > 0.0 && e.fpga_pj > 0.0);
        assert!(e.total_pj() > e.arithmetic_pj);
        assert!(e.macs_per_nj(macs) > 0.0);
    }

    #[test]
    fn traffic_macs_match_problem() {
        let t = Traffic::for_block(256, 256, 2048, 1);
        assert_eq!(t.macs, 256 * 256 * 2048);
        // Cr: 1024 kernels × 192 B.
        assert_eq!(t.ddr_bytes, 1024 * 192);
    }

    #[test]
    fn multicast_reduces_fpga_traffic_with_tiles() {
        let t1 = Traffic::for_block(256, 256, 2048, 1);
        let t8 = Traffic::for_block(256, 256, 2048, 8);
        assert!(t8.fpga_bytes < t1.fpga_bytes, "multicast amortises Ar reads");
        assert_eq!(t1.local_bytes, t8.local_bytes, "local reads are per kernel");
    }

    #[test]
    fn onchip_movement_cheaper_per_byte_than_ddr() {
        let m = EnergyModel::default();
        assert!(m.pj_per_byte_local < m.pj_per_byte_fpga);
        assert!(m.pj_per_byte_fpga < m.pj_per_byte_ddr);
    }

    #[test]
    fn parallelism_saves_static_energy() {
        // Same work, fewer wall cycles × more tiles: static energy equal;
        // but the multicast saving shows in fpga_pj.
        let (e1, macs) = block_energy(1);
        let (e8, _) = block_energy(8);
        assert!(e8.fpga_pj < e1.fpga_pj);
        assert!(e8.macs_per_nj(macs) > e1.macs_per_nj(macs));
    }
}
