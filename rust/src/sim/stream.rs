//! The streaming interface: Ar vector reads and the Br copy.
//!
//! Three regimes for reading 64-element (64 B) UINT8 vectors of Ar from
//! the FPGA Ultra RAM (§5.1, §5.3, Table 3):
//!
//! 1. **isolated** — one v64 read: 19 cycles.
//! 2. **fused pair** — the compiler/hardware rewrites two back-to-back
//!    v64 reads (`ar0`, `ar1`) as one 128-element read: 32 cycles per
//!    pair (+10 residual per kernel), reproducing Table 3's measured
//!    4106 = 128·32 + 10 against the 4864 = 128·(19+19) theory.
//! 3. **steady state** — across consecutive micro-kernels of a full GEMM
//!    the stream never stops and pipelines at ≈28 cycles/pair (reverse-
//!    engineered from Table 2's one-tile total; see DESIGN.md §6).

use crate::arch::VersalArch;

/// Streaming-interface cost model bound to an architecture.
#[derive(Debug, Clone)]
pub struct Stream<'a> {
    arch: &'a VersalArch,
}

impl<'a> Stream<'a> {
    pub fn new(arch: &'a VersalArch) -> Stream<'a> {
        Stream { arch }
    }

    /// Cycles for one isolated 64-element vector read.
    pub fn v64_cycles(&self) -> u64 {
        self.arch.ic.stream_v64_cycles
    }

    /// Cycles for a fused pair of consecutive v64 reads (one iteration of
    /// loop L6 reads ar0+ar1).
    pub fn fused_pair_cycles(&self) -> u64 {
        self.arch.ic.stream_v64_fused_pair_cycles
    }

    /// Cycles for a fused pair in the steady-state (uninterrupted stream
    /// across micro-kernels).
    pub fn steady_pair_cycles(&self) -> u64 {
        self.arch.ic.stream_steady_pair_cycles
    }

    /// Total Ar streaming cycles for a micro-kernel over `kc` (unroll 16 ⇒
    /// kc/16 iterations, each reading one fused pair).
    ///
    /// `steady` selects regime 3 (full-GEMM) vs regime 2 (isolated kernel,
    /// the Table 3 measurement condition).
    pub fn ar_stream_cycles(&self, kc: usize, steady: bool) -> u64 {
        self.ar_stream_cycles_p(kc, steady, crate::gemm::Precision::U8)
    }

    /// [`Stream::ar_stream_cycles`] for any element precision: one
    /// unrolled iteration streams mr·16 = 128 *elements* of Ar, i.e. one
    /// fused 128-byte pair per byte of element width — 2-byte elements
    /// (i16/bf16) issue two fused pairs per iteration.
    pub fn ar_stream_cycles_p(
        &self,
        kc: usize,
        steady: bool,
        prec: crate::gemm::Precision,
    ) -> u64 {
        assert!(kc % 16 == 0, "kc must be a multiple of the unroll factor 16");
        let iters = (kc / 16) as u64;
        let per_pair = if steady { self.steady_pair_cycles() } else { self.fused_pair_cycles() };
        iters * per_pair * prec.elem_bytes() + self.arch.ic.stream_fused_residual_cycles
    }

    /// The paper's *theoretical* (unfused) Ar cost: kc/16 · 2 · 19.
    pub fn ar_stream_cycles_theoretical(&self, kc: usize) -> u64 {
        (kc as u64 / 16) * 2 * self.v64_cycles()
    }

    /// Cycles to copy a Br micro-panel (`bytes`) from Block RAM into the
    /// AIE local memory over the streaming interface (§5.1: 16 KB in 3280
    /// cycles, independent of the number of tiles doing it concurrently).
    pub fn br_copy_cycles(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.arch.ic.br_copy_bytes_per_cycle).round() as u64
            + self.arch.ic.br_copy_setup_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vc1902;

    #[test]
    fn isolated_kernel_matches_table3_read_ar_row() {
        let a = vc1902();
        let s = Stream::new(&a);
        assert_eq!(s.ar_stream_cycles(2048, false), 4106); // measured
        assert_eq!(s.ar_stream_cycles_theoretical(2048), 4864); // theory
    }

    #[test]
    fn steady_state_is_cheaper_than_isolated() {
        let a = vc1902();
        let s = Stream::new(&a);
        assert!(s.ar_stream_cycles(2048, true) < s.ar_stream_cycles(2048, false));
        // 128·28 + 10 = 3594
        assert_eq!(s.ar_stream_cycles(2048, true), 3594);
    }

    #[test]
    fn br_copy_matches_5_1() {
        let a = vc1902();
        let s = Stream::new(&a);
        assert_eq!(s.br_copy_cycles(2048 * 8), 3280);
    }

    #[test]
    fn ar_cycles_scale_linearly_in_kc() {
        let a = vc1902();
        let s = Stream::new(&a);
        let base = s.ar_stream_cycles(1024, false);
        let double = s.ar_stream_cycles(2048, false);
        let resid = a.ic.stream_fused_residual_cycles;
        assert_eq!(double - resid, 2 * (base - resid));
    }

    #[test]
    #[should_panic(expected = "multiple of the unroll factor")]
    fn kc_must_be_multiple_of_16() {
        let a = vc1902();
        Stream::new(&a).ar_stream_cycles(100, false);
    }

    #[test]
    fn wide_elements_double_the_pair_traffic() {
        use crate::gemm::Precision;
        let a = vc1902();
        let s = Stream::new(&a);
        // u8 instance must equal the seed-era model exactly.
        assert_eq!(s.ar_stream_cycles_p(2048, false, Precision::U8), 4106);
        assert_eq!(s.ar_stream_cycles_p(2048, false, Precision::I8), 4106);
        // 2-byte elements: twice the fused pairs, same residual.
        let resid = a.ic.stream_fused_residual_cycles;
        assert_eq!(s.ar_stream_cycles_p(2048, false, Precision::I16), 2 * (4106 - resid) + resid);
        assert_eq!(
            s.ar_stream_cycles_p(2048, true, Precision::Bf16),
            2 * (3594 - resid) + resid
        );
    }
}
