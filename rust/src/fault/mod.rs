//! Deterministic, seeded fault injection for the cycle-domain stack.
//!
//! A production fleet is defined by how it behaves when a device drops,
//! a NoC link degrades, or a batch execution throws — not by its healthy
//! steady state. This module is the **one fault vocabulary** shared by
//! the serving runtime, the cluster layer and the test batteries:
//!
//! - [`FaultKind`] — the typed fault taxonomy: whole-device failure,
//!   AIE-tile attrition (the device keeps running with fewer tiles),
//!   fabric link degradation (bandwidth scaled down, outage at the
//!   floor), transient batch-execution errors, and the every-Nth-batch
//!   flaky schedule the legacy wall-clock coordinator tests exercised.
//! - [`FaultPlan`] — a cycle-domain **schedule** of fault events on the
//!   same logical-µs clock the serving runtime advances on. Plans come
//!   from an explicit list, the CLI grammar ([`FaultPlan::parse`]), or a
//!   seeded storm generator ([`FaultPlan::storm`]) built on the exact
//!   `splitmix64`-chained [`Pcg32`] discipline of
//!   [`crate::coordinator::workload`] — same seed, same storm, byte for
//!   byte.
//! - [`FaultInjector`] — the runtime-side state machine: fires due
//!   events as the clock advances, tracks surviving capacity, and
//!   decides which batch launches fail transiently. An injector built
//!   from [`FaultPlan::none`] is **observationally free**: it fires
//!   nothing, fails nothing, and the serving runtime's reports, metric
//!   fingerprints and Chrome traces are byte-identical to a run without
//!   any injector at all (pinned by `tests/fault_tolerance.rs`).
//! - [`RetryPolicy`] — bounded retry with deadline-aware exponential
//!   backoff and a per-tenant retry budget, consumed by
//!   [`crate::coordinator::ServingRuntime`].
//!
//! Everything here is deterministic: no wall-clock reads, no hash-map
//! iteration, integer arithmetic in the schedule domain.

use crate::util::rng::{splitmix64, Pcg32};

/// One typed fault. Times live on the caller's logical clock (the
/// serving runtime's microseconds); device indices are interpreted by
/// the consumer — the serving runtime maps them onto its pipeline
/// devices, the cluster layer onto pool [`crate::cluster::DeviceId`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Whole-device failure: the device accepts no further work and is
    /// quarantined out of the placement.
    DeviceFail {
        /// Index of the failed device.
        device: usize,
    },
    /// AIE-tile attrition: `device` keeps running but `lost` of its
    /// tiles are gone — its capacity (and therefore its share of a
    /// capacity-weighted placement) shrinks.
    TileAttrition {
        /// Index of the degraded device.
        device: usize,
        /// Tiles lost (clamped so at least one tile survives).
        lost: usize,
    },
    /// Fabric link degradation: every link's bandwidth drops to
    /// `percent`% of nominal (clamped to `1..=100`; 1% models a
    /// near-outage — a fabric with zero bandwidth would divide by zero,
    /// and a true outage is a [`FaultKind::DeviceFail`] of the
    /// unreachable device).
    LinkDegrade {
        /// Surviving bandwidth, percent of nominal.
        percent: u32,
    },
    /// The next `count` batch executions fail transiently (retryable:
    /// the work itself is fine, the execution attempt was lost).
    Transient {
        /// Batch executions to fail.
        count: u32,
    },
    /// Every `every`-th batch launch fails transiently from this event
    /// on — the deterministic schedule behind the legacy
    /// `FlakyBackend` scenarios (`tests/coordinator_faults.rs`), now
    /// shared by both runtimes.
    Flaky {
        /// Failure period in batches (0 disables).
        every: u32,
    },
}

/// A fault at a point on the logical clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// When the fault strikes (logical µs).
    pub at_us: u64,
    /// What breaks.
    pub kind: FaultKind,
}

/// A deterministic schedule of faults, sorted by time (stable — equal
/// times keep declaration order).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FaultPlan {
    /// The events, ascending by [`FaultEvent::at_us`].
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: injects nothing, observationally free.
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    /// A plan from explicit events (sorted stably by time).
    pub fn new(mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by_key(|e| e.at_us);
        FaultPlan { events }
    }

    /// Whether the plan schedules anything.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The canonical single-device-loss scenario: `device` fails at
    /// `at_us` (the acceptance gate of `bench_faults`).
    pub fn single_device_loss(device: usize, at_us: u64) -> FaultPlan {
        FaultPlan::new(vec![FaultEvent { at_us, kind: FaultKind::DeviceFail { device } }])
    }

    /// Parse the CLI grammar: comma-separated events, each
    /// `<kind>@<t_us>` (`@0` if omitted):
    ///
    /// - `device:<d>@<t>` — device `d` fails at `t` µs;
    /// - `tiles:<d>:<lost>@<t>` — device `d` loses `lost` tiles;
    /// - `link:<percent>@<t>` — links degrade to `percent`% bandwidth;
    /// - `transient:<count>@<t>` — the next `count` batches fail;
    /// - `flaky:<every>@<t>` — every `every`-th batch fails from `t` on.
    ///
    /// Example: `--faults device:1@5000,transient:2@2000,link:50@8000`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (body, at_us) = match part.rsplit_once('@') {
                Some((b, t)) => {
                    let t: u64 =
                        t.trim().parse().map_err(|_| format!("bad time in fault {part:?}"))?;
                    (b.trim(), t)
                }
                None => (part, 0),
            };
            let fields: Vec<&str> = body.split(':').map(str::trim).collect();
            let int = |s: &str, what: &str| -> Result<u64, String> {
                s.parse().map_err(|_| format!("bad {what} in fault {part:?}"))
            };
            let kind = match fields.as_slice() {
                ["device", d] => FaultKind::DeviceFail { device: int(d, "device")? as usize },
                ["tiles", d, l] => FaultKind::TileAttrition {
                    device: int(d, "device")? as usize,
                    lost: int(l, "tile count")? as usize,
                },
                ["link", p] => {
                    let percent = int(p, "percent")? as u32;
                    if percent > 100 {
                        return Err(format!("link percent must be <= 100 in {part:?}"));
                    }
                    FaultKind::LinkDegrade { percent: percent.max(1) }
                }
                ["transient", c] => FaultKind::Transient { count: int(c, "count")? as u32 },
                ["flaky", e] => FaultKind::Flaky { every: int(e, "period")? as u32 },
                _ => {
                    return Err(format!(
                        "unknown fault {part:?} (device:<d>|tiles:<d>:<lost>|link:<pct>|\
                         transient:<n>|flaky:<n>, each @<t_us>)"
                    ))
                }
            };
            events.push(FaultEvent { at_us, kind });
        }
        Ok(FaultPlan::new(events))
    }

    /// A seeded random fault storm: `n_events` faults drawn uniformly
    /// over `[0, horizon_us)` against a pool of `devices` devices. Uses
    /// the workload generator's seeding discipline — one `splitmix64`
    /// chain forks a per-stream [`Pcg32`] — so the same seed yields the
    /// same storm on every platform, independent of any other RNG use
    /// in the process.
    pub fn storm(seed: u64, horizon_us: u64, n_events: usize, devices: usize) -> FaultPlan {
        let mut chain = seed;
        let mut rng = Pcg32::new(splitmix64(&mut chain));
        let mut events = Vec::with_capacity(n_events);
        for _ in 0..n_events {
            let at_us = (rng.f64() * horizon_us.max(1) as f64) as u64;
            let kind = match rng.below(4) {
                0 => FaultKind::DeviceFail { device: rng.range(0, devices.max(1)) },
                1 => FaultKind::TileAttrition {
                    device: rng.range(0, devices.max(1)),
                    lost: 1 + rng.range(0, 4),
                },
                2 => FaultKind::LinkDegrade { percent: 10 + rng.below(90) },
                _ => FaultKind::Transient { count: 1 + rng.below(3) },
            };
            events.push(FaultEvent { at_us, kind });
        }
        FaultPlan::new(events)
    }
}

/// Bounded-retry policy for transiently failed batches: a failed
/// request re-enters batch forming only while its attempt count, its
/// tenant's retry budget **and its SLO deadline** all admit the retry;
/// otherwise it is counted `failed` in the conservation ledger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Retries allowed per request (0 = fail on first transient error,
    /// the legacy drop-cleanly behaviour).
    pub max_retries: u32,
    /// Base backoff before the first retry (logical µs); doubles per
    /// subsequent attempt. A retry whose backoff lands at or past the
    /// request's deadline is never launched — the request fails instead.
    pub backoff_us: u64,
    /// Retries one tenant may consume over the runtime's lifetime, so a
    /// fault storm in one tenant's traffic cannot starve the others'
    /// forming capacity with retry churn.
    pub tenant_retry_budget: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff_us: 500, tenant_retry_budget: 1_024 }
    }
}

/// Runtime-side fault state machine: feed it the logical clock
/// ([`FaultInjector::advance`]) and ask it, per batch launch, whether
/// the execution attempt is lost ([`FaultInjector::batch_fails`]).
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    next_event: usize,
    policy: RetryPolicy,
    failed_devices: Vec<usize>,
    tiles_lost: Vec<(usize, usize)>,
    link_percent: u32,
    transient_pending: u32,
    flaky_every: u32,
    batch_seq: u64,
    injected: u64,
    first_fault_us: Option<u64>,
}

impl FaultInjector {
    /// An injector for `plan` with the default [`RetryPolicy`].
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector {
            plan,
            next_event: 0,
            policy: RetryPolicy::default(),
            failed_devices: Vec::new(),
            tiles_lost: Vec::new(),
            link_percent: 100,
            transient_pending: 0,
            flaky_every: 0,
            batch_seq: 0,
            injected: 0,
            first_fault_us: None,
        }
    }

    /// Builder: override the retry policy.
    pub fn with_policy(mut self, policy: RetryPolicy) -> FaultInjector {
        self.policy = policy;
        self
    }

    /// The retry policy in force.
    pub fn policy(&self) -> RetryPolicy {
        self.policy
    }

    /// The schedule this injector replays.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Fire every event due at or before `now_us`, in schedule order,
    /// and return them so the caller can apply layer-specific effects
    /// (quarantine a pipeline device, tighten admission). Idempotent
    /// per event: each fires exactly once.
    pub fn advance(&mut self, now_us: u64) -> Vec<FaultEvent> {
        let mut fired = Vec::new();
        while self.next_event < self.plan.events.len()
            && self.plan.events[self.next_event].at_us <= now_us
        {
            let ev = self.plan.events[self.next_event];
            self.next_event += 1;
            self.injected += 1;
            self.first_fault_us.get_or_insert(ev.at_us);
            match ev.kind {
                FaultKind::DeviceFail { device } => {
                    if !self.failed_devices.contains(&device) {
                        self.failed_devices.push(device);
                        self.failed_devices.sort_unstable();
                    }
                }
                FaultKind::TileAttrition { device, lost } => {
                    match self.tiles_lost.iter_mut().find(|(d, _)| *d == device) {
                        Some((_, l)) => *l += lost,
                        None => {
                            self.tiles_lost.push((device, lost));
                            self.tiles_lost.sort_unstable();
                        }
                    }
                }
                FaultKind::LinkDegrade { percent } => {
                    self.link_percent = percent.clamp(1, 100);
                }
                FaultKind::Transient { count } => {
                    self.transient_pending = self.transient_pending.saturating_add(count);
                }
                FaultKind::Flaky { every } => {
                    self.flaky_every = every;
                }
            }
            fired.push(ev);
        }
        fired
    }

    /// Account one batch launch; `true` means this execution attempt is
    /// lost to an injected transient fault (a pending
    /// [`FaultKind::Transient`] count, consumed one per batch, or the
    /// [`FaultKind::Flaky`] period striking). Deterministic in the
    /// launch sequence.
    pub fn batch_fails(&mut self) -> bool {
        self.batch_seq += 1;
        if self.transient_pending > 0 {
            self.transient_pending -= 1;
            return true;
        }
        self.flaky_every > 0 && self.batch_seq % self.flaky_every as u64 == 0
    }

    /// Devices failed so far (sorted, deduplicated).
    pub fn failed_devices(&self) -> &[usize] {
        &self.failed_devices
    }

    /// Tiles lost to attrition so far, per device (sorted by device).
    pub fn tiles_lost(&self) -> &[(usize, usize)] {
        &self.tiles_lost
    }

    /// Current fabric bandwidth, percent of nominal (100 = healthy).
    pub fn link_percent(&self) -> u32 {
        self.link_percent
    }

    /// Events fired so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    /// When the first fault struck, if any has.
    pub fn first_fault_us(&self) -> Option<u64> {
        self.first_fault_us
    }

    /// Surviving fraction of a `devices`-device pool under the
    /// device-loss faults fired so far (tile attrition and link
    /// degradation are *not* folded in — they degrade throughput, not
    /// device count). Never returns 0: at least one device survives
    /// (the consumers refuse to kill the last device).
    pub fn capacity_fraction(&self, devices: usize) -> f64 {
        if devices == 0 {
            return 1.0;
        }
        let dead = self.failed_devices.iter().filter(|&&d| d < devices).count();
        let alive = devices.saturating_sub(dead).max(1);
        alive as f64 / devices as f64
    }
}

/// The shared every-Nth decision of the flaky schedule: batch `n`
/// (1-based) fails iff `every > 0` and `n` is a multiple of `every`.
/// Both the legacy wall-clock `FlakyBackend` tests and the injector's
/// [`FaultKind::Flaky`] path delegate here, so the two runtimes cannot
/// drift apart on what "every 3rd batch fails" means.
pub fn flaky_fails(n: u64, every: u64) -> bool {
    every > 0 && n % every == 0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_kind() {
        let p = FaultPlan::parse("device:1@5000, tiles:0:4@2000, link:50@8000, transient:2, flaky:3@1")
            .unwrap();
        assert_eq!(p.events.len(), 5);
        // Sorted by time, stably.
        assert_eq!(p.events[0], FaultEvent { at_us: 0, kind: FaultKind::Transient { count: 2 } });
        assert_eq!(p.events[1].kind, FaultKind::Flaky { every: 3 });
        assert_eq!(p.events[2], FaultEvent {
            at_us: 2000,
            kind: FaultKind::TileAttrition { device: 0, lost: 4 },
        });
        assert_eq!(p.events[3], FaultEvent {
            at_us: 5000,
            kind: FaultKind::DeviceFail { device: 1 },
        });
        assert_eq!(p.events[4], FaultEvent {
            at_us: 8000,
            kind: FaultKind::LinkDegrade { percent: 50 },
        });
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(FaultPlan::parse("device").is_err());
        assert!(FaultPlan::parse("device:x").is_err());
        assert!(FaultPlan::parse("link:200").is_err(), "percent > 100");
        assert!(FaultPlan::parse("meteor:1").is_err());
        assert!(FaultPlan::parse("device:1@soon").is_err());
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn storm_is_seed_deterministic_and_in_horizon() {
        let a = FaultPlan::storm(42, 10_000, 16, 4);
        let b = FaultPlan::storm(42, 10_000, 16, 4);
        assert_eq!(a, b, "same seed, same storm");
        let c = FaultPlan::storm(43, 10_000, 16, 4);
        assert_ne!(a, c, "different seed, different storm");
        assert_eq!(a.events.len(), 16);
        assert!(a.events.iter().all(|e| e.at_us < 10_000));
        assert!(a.events.windows(2).all(|w| w[0].at_us <= w[1].at_us), "sorted");
    }

    #[test]
    fn injector_fires_each_event_once_in_order() {
        let plan = FaultPlan::parse("transient:1@100,device:0@200,device:1@300").unwrap();
        let mut inj = FaultInjector::new(plan);
        assert!(inj.advance(50).is_empty());
        assert_eq!(inj.first_fault_us(), None);
        let fired = inj.advance(250);
        assert_eq!(fired.len(), 2);
        assert_eq!(inj.injected(), 2);
        assert_eq!(inj.first_fault_us(), Some(100));
        assert_eq!(inj.failed_devices(), &[0]);
        // Re-advancing past the same point fires nothing new.
        assert!(inj.advance(250).is_empty());
        let fired = inj.advance(1_000);
        assert_eq!(fired.len(), 1);
        assert_eq!(inj.failed_devices(), &[0, 1]);
    }

    #[test]
    fn capacity_fraction_counts_device_losses_only() {
        let mut inj = FaultInjector::new(FaultPlan::parse(
            "device:1@0,tiles:0:2@0,link:10@0,device:7@0",
        )
        .unwrap());
        inj.advance(0);
        // Device 7 is outside a 2-device pool; tile/link faults don't
        // change the device count.
        assert_eq!(inj.capacity_fraction(2), 0.5);
        assert_eq!(inj.link_percent(), 10);
        assert_eq!(inj.tiles_lost(), &[(0, 2)]);
        // The last device never "fails" capacity to zero.
        let mut all = FaultInjector::new(FaultPlan::parse("device:0@0,device:1@0").unwrap());
        all.advance(0);
        assert_eq!(all.capacity_fraction(2), 0.5);
    }

    #[test]
    fn transient_counts_and_flaky_period_drive_batch_failures() {
        let plan = FaultPlan::parse("transient:2@0").unwrap();
        let mut inj = FaultInjector::new(plan);
        inj.advance(0);
        assert!(inj.batch_fails());
        assert!(inj.batch_fails());
        assert!(!inj.batch_fails(), "count exhausted");
        let mut flaky = FaultInjector::new(FaultPlan::parse("flaky:3@0").unwrap());
        flaky.advance(0);
        let fails: Vec<bool> = (0..9).map(|_| flaky.batch_fails()).collect();
        assert_eq!(fails.iter().filter(|&&f| f).count(), 3, "every 3rd of 9");
        assert!(fails[2] && fails[5] && fails[8]);
        // The helper the legacy tests share.
        assert!(flaky_fails(3, 3) && flaky_fails(6, 3));
        assert!(!flaky_fails(4, 3) && !flaky_fails(5, 0));
    }

    #[test]
    fn empty_plan_is_observationally_free() {
        let mut inj = FaultInjector::new(FaultPlan::none());
        assert!(inj.advance(u64::MAX).is_empty());
        assert!(!inj.batch_fails());
        assert_eq!(inj.injected(), 0);
        assert_eq!(inj.capacity_fraction(4), 1.0);
        assert_eq!(inj.link_percent(), 100);
    }
}
