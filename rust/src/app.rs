//! CLI driver for the `versal-gemm` binary (the L3 leader entrypoint).

use crate::arch::{vc1902, VersalArch};
use crate::coordinator::{
    generate, ArrivalGen, ArrivalKind, ArrivalProcess, BatcherConfig, Coordinator,
    CoordinatorConfig, FeatureGen, PrecisionMix, RustGemmBackend, ServingConfig, ServingRuntime,
    TenantClass, WorkloadSpec,
};
use crate::dl::MlpSpec;
use crate::gemm::ablation::{evaluate, LoopChoice};
use crate::gemm::{Ccp, GemmConfig, MatI32, MatU8, ParallelGemm};
use crate::runtime::ThreadPool;
use crate::util::cli::Args;
use crate::util::ini::Ini;
use crate::util::tabulate::{Align, Table};
use crate::util::Pcg32;
use std::sync::Arc;
use std::time::{Duration, Instant};

const HELP: &str = "\
versal-gemm — GotoBLAS2 GEMM on a simulated AMD Versal ACAP (paper repro)

USAGE: versal-gemm <command> [options]

COMMANDS:
  inspect                      print the architecture (paper Table 1)
  table2   [--tiles 1,2,...]   regenerate Table 2 (strong scaling)
  table3                       regenerate Table 3 (micro-kernel ablations)
  gemm     --m M --n N --k K [--tiles T] [--seed S]
           [--engine sequential|threads] [--workers W] [--pack-parallel]
                               run a parallel GEMM, verify vs naive,
                               report cycles + MACs/cycle. --engine
                               threads executes the plan's independent
                               blocks on a work-stealing host pool
                               (--workers W; 0 = auto) with a pinned
                               reduction order, so results and cycles
                               are bit-identical to sequential — only
                               host wall time changes. --pack-parallel
                               (or PALLAS_PACK_PARALLEL=1) additionally
                               splits each pack step into disjoint panel
                               slices across the pool workers — still
                               bit-identical
  ccp      [--elem-bytes B]    derive cache configuration parameters (§4.3)
  tune     --m M --n N --k K [--tiles T]
                               auto-tune CCPs for a problem shape (model-
                               driven search; extension of §4.3)
  plan     --m M --n N --k K [--precision u8|i8|i16|bf16] [--tiles T]
           [--mc MC --nc NC --kc KC] [--count-packing] [--prepacked]
           [--cost-only] [--trace-out FILE] [--engine sequential|threads]
                               lower the problem to the unified execution
                               plan: the explicit L1/L2/L3 loop nest with
                               edge-trimmed extents, the packing steps and
                               their memory-level destinations, the per-
                               level footprint/residency table (validated
                               against Table 1's capacities), and the
                               predicted schedule the drivers will execute.
                               --cost-only prices the shape through the
                               streaming path (no step vector is ever
                               materialized — O(1) memory per shape);
                               --trace-out writes the lowered plan's
                               pack/compute/release timeline as Chrome
                               trace-event JSON (Perfetto-loadable).
                               The plan and its predicted cycles are
                               engine-independent (--engine is accepted
                               for flag compatibility with gemm/serve)
  energy   [--tiles T]         energy estimate of the paper problem
                               (extension; pJ model over the breakdown)
  noc      [--tiles T]         NoC placement + multicast/fan-out costs
  trace    [--tiles T] [--width W]
                               render the block schedule as a text gantt
                               chart (the §5.3 overlap, visualised)
  ablation [--tiles T]         compare parallelising L1/L3/L4/L5 (§4.4)
  precision [--tiles T] [--budget E]
                               mixed-precision suite (§4.2): per-precision
                               MACs/cycle on the Table-2 problem, numeric
                               conformance spot-check, and the adaptive
                               precision the tuner picks for budget E
  cluster  [--devices 1,2,4,8] [--tiles T] [--fabric pcie|cxl|ethernet]
           [--faults SPEC]     device-level strong scaling: the Table-2
                               problem sharded SUMMA-style across a pool
                               of simulated devices (extension).
                               --faults (e.g. device:1@0,tiles:0:4@0,
                               link:50@0) additionally quarantines the
                               failed devices, replans the SUMMA grid
                               over the survivors and prints the
                               plan-IR-priced recovery cost (re-pack +
                               band transfer cycles)
  serve    --requests R [--rate Q] [--batch B] [--tiles T] [--seed S]
           [--mix u8:8,i16:3,bf16:1] [--slo-ms M] [--cache-mb MB]
           [--plan-cache-mb MB] [--devices D]
           [--arrival poisson|uniform|bursty|pareto|diurnal] [--burst F]
           [--tenants gold:1:3:20,silver:2:2:60,free:4:1:200]
           [--offered-load Q]
           [--engine runtime|threads|coordinator] [--workers W]
           [--pack-parallel] [--fanout] [--trace-out FILE]
           [--faults SPEC]
                               replay a synthetic mixed-precision request
                               trace through the continuous-batching
                               runtime (admission SLOs, fused same-
                               precision batches, weight-stationary packed
                               cache, lowered-plan cache, pipelined
                               pack/transfer/compute); report latency
                               percentiles + cache hit rates. --tenants
                               (name:weight:priority:slo_ms entries)
                               switches to the multi-tenant workload
                               generator: offered traffic is split by
                               weight, cache budgets are partitioned per
                               tenant, admission sheds lowest-priority
                               first, and a per-tenant goodput/shed table
                               is printed. --offered-load aliases --rate;
                               --burst sets the bursty process's
                               burst:idle rate ratio. --engine threads
                               runs the same deterministic runtime with
                               GEMM numerics on the work-stealing host
                               pool (--workers W; 0 = auto) — reports
                               and traces are bit-identical to runtime;
                               --pack-parallel additionally parallelises
                               the pack steps (threads engine only);
                               --fanout launches independent fused
                               batches from distinct tenants
                               concurrently on the host pool with a
                               deterministic fixed-order merge — still
                               bit-identical to sequential ticks;
                               --engine coordinator runs the wall-clock
                               threaded coordinator instead;
                               --trace-out writes the
                               end-to-end request spans + pipeline stage
                               spans as Chrome trace-event JSON and
                               prints the unified metrics registry;
                               --faults attaches a deterministic fault
                               injector (runtime/threads engines):
                               comma-separated device:D@T, tiles:D:N@T,
                               link:PCT@T, transient:N@T, flaky:N@T
                               events fire at logical µs T, requests
                               retry with deadline-aware backoff and the
                               report gains fault/recovery accounting
  bench-trend PREV CURR [--threshold PCT] [--fail-on-regress]
                               diff two BENCH_*.json artifacts metric by
                               metric (flattened numeric paths): delta
                               table, with cycle-domain metrics that
                               grew more than PCT% (default 5) flagged
                               as regressions. Advisory by default;
                               --fail-on-regress makes them exit 2.
                               Artifacts whose top-level \"schema\" tags
                               differ reset the baseline: the diff is
                               skipped and the run exits 0
  help                         show this text

GLOBAL OPTIONS:
  --arch-config FILE           INI overrides for the architecture preset
";

/// The host thread pool behind `--engine threads`: `--workers W` pins
/// the crew size; `--workers 0` (the default) falls back to the
/// `PALLAS_POOL_SIZE` environment variable, then to the machine's
/// available parallelism.
fn host_pool(args: &Args) -> Result<Arc<ThreadPool>, String> {
    let workers: usize = args.get_num("workers", 0)?;
    Ok(Arc::new(if workers == 0 { ThreadPool::from_env() } else { ThreadPool::new(workers) }))
}

fn load_arch(args: &Args) -> Result<VersalArch, String> {
    let base = vc1902();
    match args.get("arch-config") {
        None => Ok(base),
        Some(path) => {
            let ini = Ini::load(std::path::Path::new(path))?;
            base.with_overrides(&ini)
        }
    }
}

/// Entry point for the `versal-gemm` binary. Returns the process exit code.
pub fn cli_main(argv: Vec<String>) -> i32 {
    match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

fn run(argv: Vec<String>) -> Result<(), String> {
    let args = Args::default()
        .opt("arch-config")
        .opt("tiles")
        .opt("m")
        .opt("n")
        .opt("k")
        .opt("seed")
        .opt("elem-bytes")
        .opt("requests")
        .opt("rate")
        .opt("batch")
        .opt("workers")
        .opt("mc")
        .opt("nc")
        .opt("kc")
        .opt("width")
        .opt("arrivals")
        .opt("arrival")
        .opt("tenants")
        .opt("offered-load")
        .opt("burst")
        .opt("devices")
        .opt("fabric")
        .opt("budget")
        .opt("mix")
        .opt("slo-ms")
        .opt("cache-mb")
        .opt("plan-cache-mb")
        .opt("engine")
        .opt("precision")
        .opt("trace-out")
        .opt("threshold")
        .opt("faults")
        .flag("count-packing")
        .flag("prepacked")
        .flag("cost-only")
        .flag("fail-on-regress")
        .flag("pack-parallel")
        .flag("fanout")
        .parse(&argv)?;
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let arch = load_arch(&args)?;

    match cmd {
        "help" | "--help" | "-h" => {
            print!("{HELP}");
            Ok(())
        }
        "inspect" => cmd_inspect(&arch),
        "table2" => {
            let tiles = args.get_list::<usize>("tiles", &[1, 2, 4, 8, 16, 32])?;
            println!("{}", crate::report::table2(&arch, &tiles).to_text());
            Ok(())
        }
        "table3" => {
            println!("{}", crate::report::table3(&arch).to_text());
            Ok(())
        }
        "gemm" => cmd_gemm(&arch, &args),
        "ccp" => cmd_ccp(&arch, &args),
        "tune" => cmd_tune(&arch, &args),
        "plan" => cmd_plan(&arch, &args),
        "energy" => cmd_energy(&arch, &args),
        "noc" => cmd_noc(&arch, &args),
        "trace" => cmd_trace(&arch, &args),
        "ablation" => cmd_ablation(&arch, &args),
        "precision" => cmd_precision(&arch, &args),
        "cluster" => cmd_cluster(&arch, &args),
        "serve" => cmd_serve(&arch, &args),
        "bench-trend" => cmd_bench_trend(&args),
        other => Err(format!("unknown command {other:?}; see `versal-gemm help`")),
    }
}

fn cmd_inspect(arch: &VersalArch) -> Result<(), String> {
    println!("{}", arch.name);
    println!(
        "AIE grid: {} tiles ({} x {}), peak {} MACs/cycle/tile (UINT8)\n",
        arch.aie.n_tiles,
        arch.aie.grid_rows,
        arch.aie.grid_cols,
        arch.peak_macs_per_cycle()
    );
    println!("{}", arch.table1().to_text());
    println!("Operand mapping (Figure 3):");
    println!("  DDR ──pack──► Bc in Block RAM ──stream──► Br in local memory");
    println!("  DDR ──pack──► Ac in Ultra RAM ──multicast──► Ar to all tiles");
    println!("  DDR ◄──GMIO──► Cr in tile vector registers");
    Ok(())
}

fn cmd_gemm(arch: &VersalArch, args: &Args) -> Result<(), String> {
    let m: usize = args.get_num("m", 256)?;
    let n: usize = args.get_num("n", 256)?;
    let k: usize = args.get_num("k", 2048)?;
    let tiles: usize = args.get_num("tiles", 8)?;
    let seed: u64 = args.get_num("seed", 0xC0FFEE)?;
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.count_packing = args.has("count-packing");
    cfg.ccp = Ccp {
        mc: args.get_num("mc", cfg.ccp.mc)?,
        nc: args.get_num("nc", cfg.ccp.nc)?,
        kc: args.get_num("kc", cfg.ccp.kc)?,
    };

    let mut rng = Pcg32::new(seed);
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let mut c = MatI32::zeros(m, n);
    let (engine, engine_desc) = match args.get_or("engine", "sequential") {
        "sequential" => (ParallelGemm::new(arch), "sequential".to_string()),
        "threads" => {
            let pool = host_pool(args)?;
            let pp = args.has("pack-parallel") || crate::runtime::pack_parallel_from_env();
            let desc = format!(
                "threads ({} pool workers{})",
                pool.workers(),
                if pp { ", parallel packing" } else { "" }
            );
            (ParallelGemm::new(arch).with_pool(pool).with_pack_parallel(pp), desc)
        }
        other => {
            return Err(format!(
                "unknown gemm engine {other:?} (want sequential|threads)"
            ))
        }
    };
    let t0 = Instant::now();
    let (cycles, stats) = engine.run(&cfg, &a, &b, &mut c).map_err(|e| e.to_string())?;
    let host = t0.elapsed();

    // Verify against the naive oracle.
    let mut want = MatI32::zeros(m, n);
    crate::gemm::baseline::naive_gemm(&a, &b, &mut want);
    let diff = c.max_abs_diff(&want);
    let macs = m as u64 * n as u64 * k as u64;

    println!("GEMM {m}x{k} · {k}x{n} on {tiles} AIE tiles, {}", cfg.ccp);
    println!("  host engine: {engine_desc}  (cycle model is engine-independent)");
    println!("  numerics: max |Δ| vs naive = {diff}  ({})", if diff == 0 { "EXACT" } else { "MISMATCH" });
    println!("  simulated cycles: total {} ({})", cycles.total, crate::report::fmt_kcycles(cycles.total));
    println!(
        "    br_copy {}  ar_stream {}  arithmetic {}  copy_cr {}  orchestration {}  packing {}",
        cycles.br_copy, cycles.ar_stream, cycles.arithmetic, cycles.copy_cr, cycles.orchestration, cycles.packing
    );
    println!(
        "  throughput: {:.1} MACs/cycle total, {:.1} per tile",
        cycles.macs_per_cycle(macs),
        cycles.macs_per_cycle(macs) / tiles as f64
    );
    let busy = stats.iter().filter(|s| s.kernels > 0).count();
    println!("  tiles busy: {busy}/{tiles}; host wall time {host:?}");
    if diff != 0 {
        return Err("numeric verification FAILED".into());
    }
    Ok(())
}

fn cmd_ccp(arch: &VersalArch, args: &Args) -> Result<(), String> {
    let elem: u64 = args.get_num("elem-bytes", 1)?;
    let raw = Ccp::derive(arch, elem);
    let aligned = Ccp::derive_aligned(arch, elem);
    println!("CCP derivation for {} ({}-byte elements):", arch.name, elem);
    println!("  raw      {raw}");
    println!("  aligned  {aligned}  (kc%16 = 0, mc%8 = 0, nc%8 = 0)");
    println!("  paper §4.3: kc ≤ 3750, mc ≈ 4500, nc ≈ 1200");
    aligned.check(arch, elem)?;
    println!("  feasibility: OK (Br/Ac/Bc/Cr all fit their levels)");
    println!("  compute-to-comm ratio at aligned kc: {:.2} MACs/byte", aligned.compute_to_comm_ratio());
    Ok(())
}

fn cmd_tune(arch: &VersalArch, args: &Args) -> Result<(), String> {
    let m: usize = args.get_num("m", 512)?;
    let n: usize = args.get_num("n", 512)?;
    let k: usize = args.get_num("k", 4096)?;
    let tiles: usize = args.get_num("tiles", 8)?;
    // The problem must admit at least one lowerable plan (the DDR
    // residency check is shape-dependent, CCP-independent): surface an
    // error instead of letting the search panic on an empty lattice.
    // PlanSpec validates in O(1) — no steps are generated for the probe.
    let mut probe = GemmConfig::paper_table2(tiles);
    probe.ccp = Ccp::derive_aligned(arch, 1);
    crate::plan::PlanSpec::new(arch, &probe, m, n, k, crate::gemm::Precision::U8, false)
        .map_err(|e| format!("({m}, {n}, {k}) does not fit the device: {e}"))?;
    let t0 = Instant::now();
    let tuned = crate::gemm::tuner::tune(arch, m, n, k, tiles);
    println!("auto-tuned CCPs for ({m}, {n}, {k}) on {tiles} tiles:");
    println!("  best     {}", tuned.ccp);
    println!("  predicted {} cycles ({:.1} MACs/cycle)",
        tuned.predicted_cycles,
        (m as u64 * n as u64 * k as u64) as f64 / tuned.predicted_cycles as f64);
    println!("  searched {} feasible candidates in {:?}", tuned.candidates_evaluated, t0.elapsed());
    let derived = Ccp::derive_aligned(arch, 1);
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.ccp = derived;
    let derived_cost = crate::gemm::tuner::predict_cycles(arch, &cfg, m, n, k);
    println!("  (§4.3 capacity-maximal {} would cost {} cycles)", derived, derived_cost);
    Ok(())
}

fn cmd_plan(arch: &VersalArch, args: &Args) -> Result<(), String> {
    use crate::gemm::Precision;
    use crate::plan::{Buffer, PlanSpec, PlanStep};

    let m: usize = args.get_num("m", 256)?;
    let n: usize = args.get_num("n", 256)?;
    let k: usize = args.get_num("k", 2048)?;
    let tiles: usize = args.get_num("tiles", 8)?;
    let prec = Precision::parse(args.get_or("precision", "u8"))?;
    if m == 0 || n == 0 || k == 0 {
        return Err("--m/--n/--k must be positive".into());
    }
    if tiles == 0 || tiles > arch.aie.n_tiles {
        return Err(format!(
            "--tiles must be in 1..={} for {}",
            arch.aie.n_tiles, arch.name
        ));
    }

    // The plan and its predicted schedule are engine-independent: both
    // host engines execute this identical step stream and charge the
    // identical cycle model. Accept (and validate) --engine anyway so
    // `plan`/`gemm` invocations stay flag-compatible.
    let plan_engine = args.get_or("engine", "sequential");
    if !matches!(plan_engine, "sequential" | "threads") {
        return Err(format!(
            "unknown plan engine {plan_engine:?} (want sequential|threads)"
        ));
    }
    if plan_engine == "threads" {
        println!(
            "note: the lowered plan and predicted cycles are engine-independent; \
             --engine threads only changes host wall time at execution"
        );
    }

    // Default geometry: the precision's feasible paper-shaped CCP, so
    // `plan --precision i16` works out of the box; --mc/--nc/--kc override.
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.ccp = crate::gemm::tuner::ccp_for_precision(arch, prec);
    cfg.ccp = Ccp {
        mc: args.get_num("mc", cfg.ccp.mc)?,
        nc: args.get_num("nc", cfg.ccp.nc)?,
        kc: args.get_num("kc", cfg.ccp.kc)?,
    };
    cfg.count_packing = args.has("count-packing");
    let cost_only = args.has("cost-only");

    let spec = PlanSpec::new(arch, &cfg, m, n, k, prec, args.has("prepacked"))
        .map_err(|e| e.to_string())?;

    println!(
        "execution plan{}: ({m}, {n}, {k}) {prec} on {tiles} AIE tiles, {}{}",
        if cost_only { " (cost-only, streaming — no step vector)" } else { "" },
        cfg.ccp,
        if spec.prepacked_b { ", B prepacked (weight-stationary)" } else { "" }
    );
    println!("\nlowered loop nest (GotoBLAS L1/L2/L3 with edge-trimmed extents):");
    // Edge extents of the last block of each loop: `dim % stride`, or a
    // full stride when it divides (what the step stream's final blocks
    // carry; the --cost-only debug block below asserts this against the
    // materialized plan's actual compute steps).
    let edge = |dim: usize, stride: usize| -> usize {
        if dim % stride == 0 {
            stride.min(dim)
        } else {
            dim % stride
        }
    };
    let (edge_m, edge_n, edge_k) =
        (edge(m, cfg.ccp.mc), edge(n, cfg.ccp.nc), edge(k, cfg.ccp.kc));
    println!(
        "  L1 jc: {:>4} block(s) x nc = {:<5} (edge block {edge_n})",
        spec.jc_blocks(),
        cfg.ccp.nc,
    );
    println!(
        "  L2 pc: {:>4} block(s) x kc = {:<5} (edge block {edge_k}) -> pack Bc into Block RAM",
        spec.pc_blocks(),
        cfg.ccp.kc,
    );
    println!(
        "  L3 ic: {:>4} block(s) x mc = {:<5} (edge block {edge_m}) -> pack Ac into Ultra RAM",
        spec.ic_blocks(),
        cfg.ccp.mc,
    );

    let cost = if cost_only {
        // The streaming path: cost the step stream as it is generated —
        // no step vector for however many blocks the nest has. The
        // step-count line comes from the closed forms.
        println!(
            "  steps: {} total — {} Bc pack(s), {} Ac pack(s), {} compute block(s), \
             {} release(s)   [streamed, not materialized]",
            spec.n_steps(),
            spec.jc_blocks() * spec.pc_blocks(),
            spec.n_compute_steps(),
            spec.n_compute_steps(),
            spec.n_compute_steps() + spec.jc_blocks() * spec.pc_blocks(),
        );
        let cost = spec.cost_streaming(arch);
        if cfg!(debug_assertions) {
            // Debug builds verify the streaming fold against the
            // materialized plan — the two must agree to the cycle.
            let plan = crate::plan::GemmPlan::lower(
                arch,
                &cfg,
                m,
                n,
                k,
                prec,
                args.has("prepacked"),
            )
            .expect("spec validated, lowering cannot fail");
            debug_assert_eq!(
                plan.cost(arch),
                cost,
                "streaming and materialized costs must agree"
            );
            debug_assert_eq!(plan.steps().len(), spec.n_steps());
            // The closed-form edge extents printed above must be the
            // extents the lowered steps actually carry (all dims are
            // positive here, so every loop's last block computes).
            let (mut pm, mut pn, mut pk) = (0usize, 0usize, 0usize);
            for s in plan.steps() {
                if let PlanStep::Compute(c) = s {
                    if c.ic + c.mc_eff == m {
                        pm = c.mc_eff;
                    }
                    if c.jc + c.nc_eff == n {
                        pn = c.nc_eff;
                    }
                    if c.pc + c.kc_eff == k {
                        pk = c.kc_eff;
                    }
                }
            }
            debug_assert_eq!(
                (pm, pn, pk),
                (edge_m, edge_n, edge_k),
                "closed-form edge extents drifted from the lowered steps"
            );
        }
        cost
    } else {
        let plan = spec.clone().materialize();
        let (mut packs_a, mut packs_b, mut releases) = (0usize, 0usize, 0usize);
        for s in plan.steps() {
            match s {
                PlanStep::Pack(p) if p.buffer == Buffer::Ac => packs_a += 1,
                PlanStep::Pack(_) => packs_b += 1,
                PlanStep::Release(_) => releases += 1,
                PlanStep::Compute(_) => {}
            }
        }
        println!(
            "  steps: {} total — {} Bc pack(s) ({}), {} Ac pack(s) ({}), {} compute block(s) \
             ({} micro-kernels), {} release(s)",
            plan.steps().len(),
            packs_b,
            crate::arch::human_bytes(plan.pack_bytes(Buffer::Bc)),
            packs_a,
            crate::arch::human_bytes(plan.pack_bytes(Buffer::Ac)),
            plan.n_compute_steps(),
            plan.micro_kernels(),
            releases,
        );
        plan.cost(arch)
    };

    println!("\nper-level footprint / residency (validated at plan time):");
    println!("{}", crate::report::footprint_table(spec.footprints()).to_text());

    let macs = spec.total_macs();
    println!("predicted schedule (the drivers execute this same plan):");
    println!(
        "  total {} cycles ({})  —  {:.1} MACs/cycle aggregate, {:.1} per tile",
        cost.total,
        crate::report::fmt_kcycles(cost.total),
        cost.macs_per_cycle(macs),
        cost.macs_per_cycle(macs) / tiles as f64
    );
    println!(
        "    br_copy {}  ar_stream {}  arithmetic {}  copy_cr {}  orchestration {}  packing {}",
        cost.br_copy, cost.ar_stream, cost.arithmetic, cost.copy_cr, cost.orchestration,
        cost.packing
    );
    println!("  effective MACs {macs} (= m*n*k; padded panel lanes retire no useful work)");

    if let Some(path) = args.get("trace-out") {
        let plan = crate::plan::GemmPlan::lower(arch, &cfg, m, n, k, prec, args.has("prepacked"))
            .map_err(|e| e.to_string())?;
        let tracer = crate::obs::Tracer::recording();
        let traced = crate::obs::trace_plan(arch, &plan, &tracer);
        std::fs::write(path, crate::obs::to_chrome_json(&tracer.snapshot()))
            .map_err(|e| format!("cannot write {path}: {e}"))?;
        println!(
            "wrote Chrome trace to {path} ({traced} traced cycles) — open in Perfetto \
             (ui.perfetto.dev) or chrome://tracing"
        );
    }
    Ok(())
}

fn cmd_energy(arch: &VersalArch, args: &Args) -> Result<(), String> {
    use crate::sim::{energy_of, EnergyModel, Traffic};
    let tiles: usize = args.get_num("tiles", 8)?;
    let engine = ParallelGemm::new(arch);
    let cfg = GemmConfig::paper_table2(tiles);
    let sched = engine.block_schedule(&cfg, 32, 32, 2048, 2048 * 8);
    let traffic = Traffic::for_block(256, 256, 2048, tiles);
    let model = EnergyModel::default();
    let e = energy_of(&model, &sched, &traffic, tiles);
    println!("energy estimate, (256, 256, 2048) on {tiles} tiles (extension):");
    println!("  arithmetic {:.2} µJ  ddr {:.2} µJ  fpga {:.2} µJ  local {:.2} µJ  static {:.2} µJ",
        e.arithmetic_pj / 1e6, e.ddr_pj / 1e6, e.fpga_pj / 1e6, e.local_pj / 1e6, e.static_pj / 1e6);
    println!("  total {:.2} µJ  ⇒  {:.1} MACs/nJ", e.total_pj() / 1e6, e.macs_per_nj(traffic.macs));
    Ok(())
}

fn cmd_noc(arch: &VersalArch, args: &Args) -> Result<(), String> {
    use crate::sim::Noc;
    let tiles: usize = args.get_num("tiles", 32)?;
    let noc = Noc::new(arch);
    let placement = noc.place(tiles).map_err(|e| e.to_string())?;
    let mc = noc.multicast_v64_cycles(&placement).map_err(|e| e.to_string())?;
    let fo = noc.fanout_v64_cycles(&placement).map_err(|e| e.to_string())?;
    let (rows, cols) = noc.dims();
    println!("NoC placement of {tiles} tiles on the {rows}x{cols} AIE array:");
    println!("  columns used: {}", placement.iter().map(|t| t.col).max().unwrap() + 1);
    println!("  Ar multicast, one v64 vector : {mc} cycles (flat in tile count — §5.1)");
    println!("  point-to-point fan-out would be: {fo} cycles (the design the paper avoided)");
    Ok(())
}

fn cmd_trace(arch: &VersalArch, args: &Args) -> Result<(), String> {
    let tiles: usize = args.get_num("tiles", 4)?;
    let width: usize = args.get_num("width", 100)?;
    let cfg = GemmConfig::paper_table2(tiles);
    let trace = crate::sim::trace_block(arch, &cfg, 32, 32, 2048, 2048 * 8);
    println!("block schedule trace, (mc, nc, kc) = (256, 256, 2048), {tiles} tiles:\n");
    println!("{}", trace.gantt(width.max(10)));
    Ok(())
}

fn cmd_ablation(arch: &VersalArch, args: &Args) -> Result<(), String> {
    let tiles: usize = args.get_num("tiles", 8)?;
    let cfg = GemmConfig::paper_table2(tiles);
    let mut t = Table::new(&["Loop", "Total cycles", "MACs/cycle/tile", "Notes"])
        .align(0, Align::Left)
        .align(3, Align::Left);
    for choice in [LoopChoice::L1, LoopChoice::L2, LoopChoice::L3, LoopChoice::L4, LoopChoice::L5, LoopChoice::L6] {
        match evaluate(arch, &cfg, choice) {
            Ok(r) => {
                let note = if choice == LoopChoice::L4 { "paper's choice" } else { "" };
                t.row(&[
                    choice.name().to_string(),
                    r.total_cycles.to_string(),
                    format!("{:.1}", r.macs_per_cycle_per_tile),
                    note.to_string(),
                ]);
            }
            Err(e) => {
                t.row(&[choice.name().to_string(), "-".into(), "-".into(), e.to_string()]);
            }
        }
    }
    println!("Loop-parallelisation ablation at {tiles} tiles, {}", cfg.ccp);
    println!("{}", t.to_text());
    Ok(())
}

fn cmd_precision(arch: &VersalArch, args: &Args) -> Result<(), String> {
    use crate::gemm::baseline::naive_gemm_p;
    use crate::gemm::precision::{Bf16, Element};
    use crate::gemm::{Mat, Precision};

    let tiles: usize = args.get_num("tiles", 8)?;
    let budget: f64 = args.get_num("budget", 1e-2)?;
    let (m, n, k) = crate::report::TABLE2_PROBLEM;

    println!("mixed-precision micro-kernel suite (§4.2), ({m}, {n}, {k}) on {tiles} tiles:\n");
    let rows = crate::report::precision_rows(arch, tiles);
    println!("{}", crate::report::precision_table(&rows).to_text());

    // Numeric conformance spot-check on a small edge shape: integers
    // bit-exact, bf16 within the f32 forward-error bound.
    let engine = ParallelGemm::new(arch);
    let mut cfg = GemmConfig::paper_table2(tiles.min(4));
    cfg.ccp = Ccp { mc: 16, nc: 16, kc: 32 };
    let (sm, sk, sn) = (21, 37, 13);
    fn check_exact<T: Element>(
        engine: &ParallelGemm<'_>,
        cfg: &GemmConfig,
        m: usize,
        k: usize,
        n: usize,
        seed: u64,
    ) -> Result<f64, String> {
        let mut rng = Pcg32::new(seed);
        let a = Mat::<T>::random(m, k, &mut rng);
        let b = Mat::<T>::random(k, n, &mut rng);
        let mut c = Mat::<T::Acc>::zeros(m, n);
        let mut want = Mat::<T::Acc>::zeros(m, n);
        engine.run_p::<T>(cfg, &a, &b, &mut c).map_err(|e| e.to_string())?;
        naive_gemm_p::<T>(&a, &b, &mut want);
        Ok(c.max_abs_diff_f64(&want))
    }
    println!("numeric conformance, ({sm}, {sk}, {sn}) edge shape vs golden reference:");
    for prec in Precision::ALL {
        let diff = match prec {
            Precision::U8 => check_exact::<u8>(&engine, &cfg, sm, sk, sn, 1)?,
            Precision::I8 => check_exact::<i8>(&engine, &cfg, sm, sk, sn, 2)?,
            Precision::I16 => check_exact::<i16>(&engine, &cfg, sm, sk, sn, 3)?,
            Precision::Bf16 => check_exact::<Bf16>(&engine, &cfg, sm, sk, sn, 4)?,
        };
        // bf16 is judged against the proven forward-error bound (both the
        // driver and the reference compute in f32 → two-sided); inputs
        // are in [−1, 1], so Σ|a·b| ≤ k. Integers must be bit-exact.
        let bound = match prec {
            Precision::Bf16 => {
                2.0 * crate::gemm::bf16_forward_error_bound(sk, sk as f64)
            }
            _ => 0.0,
        };
        let ok = diff <= bound;
        let verdict = match prec {
            Precision::Bf16 if ok => format!("ULP-BOUNDED (|Δ| {diff:.2e} ≤ {bound:.2e})"),
            Precision::Bf16 => format!("OUT OF BOUND (|Δ| {diff:.2e} > {bound:.2e})"),
            _ if ok => "EXACT".to_string(),
            _ => format!("MISMATCH |Δ| = {diff}"),
        };
        println!("  {:<5} {verdict}", prec.to_string());
        if !ok {
            return Err(format!("{prec} conformance failed: {verdict}"));
        }
    }

    // Adaptive selection across budgets, the requested one highlighted.
    println!("\nadaptive precision selection for ({m}, {n}, {k}):");
    let mut budgets = vec![0.5, 1e-2, 1e-4];
    if !budgets.contains(&budget) {
        budgets.push(budget);
    }
    for b in budgets {
        match crate::gemm::select_precision(arch, m, n, k, tiles, b) {
            Some(c) => println!(
                "  budget {b:<8.1e} → {:<5} ({} predicted cycles, rel err {:.1e}){}",
                c.precision.to_string(),
                c.predicted_cycles,
                c.predicted_rel_error,
                if b == budget { "   ← --budget" } else { "" }
            ),
            None => println!("  budget {b:<8.1e} → none feasible (fall back to bf16)"),
        }
    }
    Ok(())
}

fn cmd_cluster(arch: &VersalArch, args: &Args) -> Result<(), String> {
    use crate::cluster::FabricSpec;
    let devices = args.get_list::<usize>("devices", &[1, 2, 4, 8])?;
    let tiles: usize = args.get_num("tiles", 8)?;
    let fabric = FabricSpec::by_name(args.get_or("fabric", "pcie"))?;
    let rows = crate::report::cluster_scaling_rows(arch, tiles, &devices, &fabric)
        .map_err(|e| e.to_string())?;
    let (m, n, k) = crate::report::TABLE2_PROBLEM;
    println!(
        "device-level strong scaling of ({m}, {n}, {k}) — SUMMA shards over ring-connected \
         {} fabric, {tiles} AIE tiles/device:\n",
        fabric.name
    );
    println!("{}", crate::report::cluster_table(&rows).to_text());
    if let (Some(first), Some(last)) = (rows.first(), rows.last()) {
        println!(
            "aggregate {:.1} → {:.1} MACs/cycle over {}→{} devices (per-device efficiency {:.0}%)",
            first.aggregate_macs_per_cycle,
            last.aggregate_macs_per_cycle,
            first.devices,
            last.devices,
            last.per_device_efficiency * 100.0
        );
    }
    if let Some(spec) = args.get("faults") {
        cluster_fault_demo(arch, spec, tiles, &devices, &fabric)?;
    }
    Ok(())
}

/// The `cluster --faults` path: apply a parsed fault plan to the
/// largest configured pool, quarantine the failed devices, replan the
/// SUMMA grid over the survivors, and price the recovery through the
/// plan IR.
fn cluster_fault_demo(
    arch: &VersalArch,
    spec: &str,
    tiles: usize,
    devices: &[usize],
    fabric: &crate::cluster::FabricSpec,
) -> Result<(), String> {
    use crate::cluster::{recovery, Cluster, Topology};
    use crate::fault::{FaultKind, FaultPlan};
    use crate::gemm::Precision;
    let plan = FaultPlan::parse(spec)?;
    let n = devices.iter().copied().max().unwrap_or(1);
    let healthy = Cluster::homogeneous(n, arch.clone(), tiles, Topology::Ring(n), fabric.clone())
        .map_err(|e| e.to_string())?;
    let mut degraded = healthy.clone();
    let mut failed: Vec<usize> = Vec::new();
    for ev in &plan.events {
        match ev.kind {
            FaultKind::DeviceFail { device } => {
                if device < n {
                    failed.push(device);
                }
            }
            FaultKind::TileAttrition { device, lost } => {
                degraded =
                    recovery::attrite_tiles(&degraded, device, lost).map_err(|e| e.to_string())?;
            }
            FaultKind::LinkDegrade { percent } => {
                degraded = recovery::degrade_links(&degraded, percent);
            }
            // Transient/flaky faults are serving-runtime events; the
            // static cluster view has no batch stream to perturb.
            FaultKind::Transient { .. } | FaultKind::Flaky { .. } => {}
        }
    }
    let (m, nn, k) = crate::report::TABLE2_PROBLEM;
    let (survived, placement, kept) =
        recovery::replan(&degraded, &failed, m, nn).map_err(|e| e.to_string())?;
    let cfg = GemmConfig::paper_table2(tiles);
    let cost = recovery::replan_cost(&survived, &placement, &cfg, k, Precision::U8)
        .map_err(|e| e.to_string())?;
    println!(
        "\nfault recovery: {} of {n} device(s) quarantined, survivors {kept:?} \
         ({} tiles) replan to a {}x{} grid on {}",
        failed.len(),
        survived.total_tiles(),
        placement.rows,
        placement.cols,
        survived.fabric.name
    );
    println!(
        "  recovery cost (plan-IR priced): re-pack {} + band transfer {} = {} cycles",
        cost.repack_cycles,
        cost.transfer_cycles,
        cost.total()
    );
    Ok(())
}

/// The arrival-process family from the CLI (`--arrival`, with the
/// historical `--arrivals` spelling as a fallback).
fn arrival_kind(args: &Args) -> Result<ArrivalKind, String> {
    match args.get("arrival") {
        Some(name) => ArrivalKind::parse(name),
        None => ArrivalKind::parse(args.get_or("arrivals", "poisson")),
    }
}

fn arrival_process(args: &Args, rate: f64) -> Result<ArrivalProcess, String> {
    let burst: f64 = args.get_num("burst", 5.0)?;
    if burst.is_nan() || burst < 1.0 {
        return Err("--burst must be a ratio of at least 1".into());
    }
    Ok(arrival_kind(args)?.process(rate, burst))
}

fn cmd_serve(arch: &VersalArch, args: &Args) -> Result<(), String> {
    match args.get_or("engine", "runtime") {
        "runtime" => cmd_serve_runtime(arch, args, false),
        "threads" => cmd_serve_runtime(arch, args, true),
        "coordinator" => cmd_serve_coordinator(arch, args),
        other => Err(format!(
            "unknown serve engine {other:?} (want runtime|threads|coordinator)"
        )),
    }
}

/// Replay a synthetic mixed-precision trace through the deterministic
/// continuous-batching runtime (logical clock, simulated cycles).
///
/// `pooled` selects `--engine threads`: the same runtime, but fused
/// batches execute their GEMM numerics on the work-stealing host pool.
/// The deterministic-reduction invariant makes results, cycle
/// accounting, reports and traces bit-identical to the sequential
/// engine — only host wall time changes.
fn cmd_serve_runtime(arch: &VersalArch, args: &Args, pooled: bool) -> Result<(), String> {
    let requests: usize = args.get_num("requests", 256)?;
    let rate: f64 = args.get_num("rate", 2000.0)?;
    let offered: f64 = args.get_num("offered-load", rate)?;
    let burst: f64 = args.get_num("burst", 5.0)?;
    let batch: usize = args.get_num("batch", 8)?;
    let tiles: usize = args.get_num("tiles", 8)?;
    let seed: u64 = args.get_num("seed", 7)?;
    let slo_ms: f64 = args.get_num("slo-ms", 50.0)?;
    let cache_mb: f64 = args.get_num("cache-mb", 64.0)?;
    let plan_cache_mb: f64 = args.get_num("plan-cache-mb", 8.0)?;
    let devices: usize = args.get_num("devices", 2)?;
    let mix = match args.get("mix") {
        Some(s) => PrecisionMix::parse(s)?,
        None => PrecisionMix::default_serving(),
    };
    let classes = match args.get("tenants") {
        Some(s) => Some(TenantClass::parse_list(s)?),
        None => None,
    };
    if batch == 0 {
        return Err("--batch must be at least 1".into());
    }
    if offered.is_nan() || offered <= 0.0 {
        return Err("--offered-load must be a positive rate (requests/second)".into());
    }
    if burst.is_nan() || burst < 1.0 {
        return Err("--burst must be a ratio of at least 1".into());
    }
    if devices == 0 {
        return Err("--devices must be at least 1".into());
    }
    if slo_ms.is_nan() || slo_ms <= 0.0 {
        return Err("--slo-ms must be positive (a zero SLO rejects every request)".into());
    }
    if cache_mb.is_nan() || cache_mb < 0.0 {
        return Err("--cache-mb must be non-negative".into());
    }
    if plan_cache_mb.is_nan() || plan_cache_mb < 0.0 {
        return Err("--plan-cache-mb must be non-negative (0 re-lowers per batch)".into());
    }
    if !pooled && args.get("workers").is_some() {
        eprintln!("note: --workers applies to --engine threads; the runtime engine ignores it");
    }
    if classes.is_some() && args.get("mix").is_some() {
        eprintln!(
            "note: --mix applies to the single-tenant trace; tenant classes draw from \
             the default serving mix"
        );
    }

    let spec = MlpSpec::default_classifier();
    println!(
        "continuous-batching runtime: quantised MLP {:?} ({} params) on {tiles} AIE tiles",
        spec.dims,
        spec.n_params()
    );
    println!(
        "  {requests} requests @ {offered}/s ({}), max batch {batch}, SLO {slo_ms} ms, \
         cache {cache_mb} MiB, plan cache {plan_cache_mb} MiB, {devices} pipeline devices",
        arrival_kind(args)?.name()
    );
    if let Some(cs) = &classes {
        let shares: Vec<String> = cs
            .iter()
            .map(|c| format!("{} (w {}, prio {}, SLO {} ms)", c.name, c.weight, c.priority, c.slo_us as f64 / 1e3))
            .collect();
        println!("  tenants: {}", shares.join(", "));
    }
    let mut backend = RustGemmBackend::new(arch.clone(), spec.clone(), seed, tiles);
    let pack_parallel = args.has("pack-parallel") || crate::runtime::pack_parallel_from_env();
    if pooled {
        let pool = host_pool(args)?;
        println!(
            "  engine: threads ({} pool workers{}; deterministic reduction — results and \
             cycles match --engine runtime bit for bit)",
            pool.workers(),
            if pack_parallel { ", parallel packing" } else { "" }
        );
        backend = backend.with_pool(pool).with_pack_parallel(pack_parallel);
    } else if pack_parallel {
        eprintln!("note: --pack-parallel applies to --engine threads; the runtime engine packs serially");
    }
    // A disabled tracer is a no-op through the whole runtime, so the
    // wiring is unconditional and only --trace-out pays for recording.
    let tracer = match args.get("trace-out") {
        Some(_) => crate::obs::Tracer::recording(),
        None => crate::obs::Tracer::disabled(),
    };
    let cfg = ServingConfig {
        max_batch: batch,
        max_wait_us: 2_000,
        queue_cap: 8_192,
        default_slo_us: (slo_ms * 1_000.0) as u64,
        cache_budget_bytes: (cache_mb * (1u64 << 20) as f64) as u64,
        plan_cache_budget_bytes: (plan_cache_mb * (1u64 << 20) as f64) as u64,
        pipeline_devices: devices,
        max_backlog_us: u64::MAX,
    };
    let mut rt = match &classes {
        Some(cs) => ServingRuntime::with_tenants(backend, cfg, cs.clone()),
        None => ServingRuntime::new(backend, cfg),
    }
    .with_tracer(tracer.clone());
    if args.has("fanout") {
        let pool = host_pool(args)?;
        println!(
            "  fan-out: distinct-tenant batches execute concurrently on {} workers \
             (fixed-order merge — reports and traces bit-identical to sequential)",
            pool.workers()
        );
        rt = rt.with_fanout(pool);
    }
    if let Some(spec) = args.get("faults") {
        let plan = crate::fault::FaultPlan::parse(spec)?;
        println!(
            "  fault injection: {} scheduled event(s) — failed devices quarantine, \
             transient batch failures retry with deadline-aware backoff",
            plan.events.len()
        );
        rt = rt.with_faults(crate::fault::FaultInjector::new(plan));
    }

    let served = match &classes {
        // Multi-tenant: the workload generator splits the offered rate
        // across the classes by weight and the runtime replays the
        // merged trace (priority admission, per-tenant partitions).
        Some(cs) => {
            let trace = generate(
                &WorkloadSpec {
                    tenants: cs.clone(),
                    kind: arrival_kind(args)?,
                    offered_rate: offered,
                    burst,
                    requests,
                    seed,
                },
                spec.dims[0],
            );
            let (out, _end) = rt.replay(&trace);
            out.len()
        }
        // Single-tenant: the historical open-loop drive.
        None => {
            let process = arrival_process(args, offered)?;
            let mut arrivals = ArrivalGen::new(process, seed);
            let mut features = FeatureGen::new(spec.dims[0], seed ^ 0xFEA7);
            let mut mix_rng = Pcg32::new(seed ^ 0x5E17E);
            let mut served = 0usize;
            let mut last_us = 0u64;
            for _ in 0..requests {
                last_us = (arrivals.next_arrival() * 1e6) as u64;
                let prec = mix.sample(&mut mix_rng);
                let _ = rt.submit(features.next(), prec, last_us);
                served += rt.tick(last_us).len();
            }
            served + rt.drain(last_us + 2_000).len()
        }
    };

    let report = rt.report();
    println!("\n{}", crate::report::serving_table(&report).to_text());
    if report.tenants.len() > 1 {
        println!("\nper-tenant accounting:");
        println!("{}", crate::report::tenant_table(&report).to_text());
    }
    if let Some(l) = &report.latency {
        println!("latency (logical µs, batch completion − arrival):");
        println!("{}", crate::report::latency_table(l).to_text());
    }
    if let Some(path) = args.get("trace-out") {
        std::fs::write(path, crate::obs::to_chrome_json(&tracer.snapshot()))
            .map_err(|e| format!("cannot write {path}: {e}"))?;
        println!(
            "wrote Chrome trace to {path} — open in Perfetto (ui.perfetto.dev) or \
             chrome://tracing"
        );
        println!("\nunified metrics registry:");
        println!("{}", crate::report::metrics_table(&report.metrics()).to_text());
    }
    println!(
        "served {served}/{requests}; fused same-precision batches amortise packing \
         exactly like larger kc amortises the Cr transfer (§4.2), and cache hits \
         skip pack_b entirely."
    );
    Ok(())
}

/// The wall-clock threaded coordinator (router + worker pool).
///
/// Unlike `runtime`/`threads`, this engine schedules on real time
/// (arrival sleeps, channel hand-offs), so its numbers are
/// machine-dependent — it demonstrates the serving topology rather
/// than the deterministic cycle model.
fn cmd_serve_coordinator(arch: &VersalArch, args: &Args) -> Result<(), String> {
    if args.get("faults").is_some() {
        return Err(
            "--faults applies to the deterministic engines (--engine runtime|threads); \
             the wall-clock coordinator has its own flaky-backend tests"
                .into(),
        );
    }
    let requests: usize = args.get_num("requests", 256)?;
    let rate: f64 = args.get_num("rate", 2000.0)?;
    let batch: usize = args.get_num("batch", 8)?;
    let workers: usize = args.get_num("workers", 2)?;
    let tiles: usize = args.get_num("tiles", 8)?;
    let seed: u64 = args.get_num("seed", 7)?;
    for flag in ["mix", "slo-ms", "cache-mb", "plan-cache-mb", "devices"] {
        if args.get(flag).is_some() {
            eprintln!(
                "note: --{flag} applies to --engine runtime|threads; the coordinator \
                 engine ignores it"
            );
        }
    }

    let spec = MlpSpec::default_classifier();
    println!(
        "serving quantised MLP {:?} ({} params) on {workers} workers × {tiles} AIE tiles",
        spec.dims,
        spec.n_params()
    );
    let arch2 = arch.clone();
    let coordinator = Coordinator::start(
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: batch,
                max_wait: Duration::from_millis(2),
                queue_cap: 8192,
            },
            n_workers: workers,
            in_dim: spec.dims[0],
        },
        move |_| Box::new(RustGemmBackend::new(arch2.clone(), MlpSpec::default_classifier(), seed, tiles)),
    );

    // Open-loop workload: arrivals from the configured process, features
    // from a reproducible generator.
    let mut arrivals = ArrivalGen::new(arrival_process(args, rate)?, seed);
    let mut features = FeatureGen::new(784, seed ^ 0xFEA7);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for _ in 0..requests {
        pending.push(coordinator.submit(features.next()).map_err(|e| e.to_string())?);
        let next = Duration::from_secs_f64(arrivals.next_arrival());
        if let Some(sleep) = next.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
    }
    coordinator.flush();
    let mut ok = 0;
    for rx in pending {
        if rx.recv().is_ok() {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let metrics = coordinator.shutdown();
    println!("  completed {ok}/{requests} in {wall:?} ({:.0} req/s)", ok as f64 / wall.as_secs_f64());
    if let Some(l) = metrics.latency_stats() {
        println!(
            "  latency µs: mean {:.0}  p50 {:.0}  p95 {:.0}  p99 {:.0}  max {:.0}",
            l.mean_us, l.p50_us, l.p95_us, l.p99_us, l.max_us
        );
    }
    println!(
        "  mean batch {:.2}, mean simulated Versal cycles/batch {:.0}",
        metrics.mean_batch_size(),
        metrics.mean_simulated_cycles()
    );
    Ok(())
}

/// `bench-trend PREV CURR`: diff two BENCH artifacts metric by metric.
///
/// Both artifacts are parsed with the crate's own JSON reader and
/// flattened to `path → number` rows (`rows[1].compute_cycles`, …).
/// Cycle-domain metrics (paths ending in `cycles`) that grew more than
/// `--threshold` percent (default 5) are flagged as regressions; the
/// throughput gauge `requests_per_mcycle` and wall-clock fields like
/// `lower_ns` are deliberately not gated. Advisory by default — CI runs
/// it with `--fail-on-regress` to turn flagged rows into exit code 2.
fn cmd_bench_trend(args: &Args) -> Result<(), String> {
    use crate::util::json::Json;

    let pos = args.positional();
    let (prev_path, curr_path) = match (pos.get(1), pos.get(2)) {
        (Some(p), Some(c)) => (p.as_str(), c.as_str()),
        _ => {
            return Err(
                "usage: bench-trend PREV.json CURR.json [--threshold PCT] [--fail-on-regress]"
                    .into(),
            )
        }
    };
    let threshold: f64 = args.get_num("threshold", 5.0)?;
    if threshold.is_nan() || threshold < 0.0 {
        return Err("--threshold must be a non-negative percentage".into());
    }

    let load = |path: &str| -> Result<Json, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("{path}: {e}"))
    };
    let prev_doc = load(prev_path)?;
    let curr_doc = load(curr_path)?;

    // Artifacts self-describe their layout with a top-level "schema"
    // tag. When the tag changes (a bench reshapes its rows), the old
    // baseline is meaningless: comparing it row by row would flag
    // phantom regressions and mask real ones. Treat it as a baseline
    // reset — report, skip the gate, exit 0 — so a schema bump never
    // needs a hand-edited baseline to get through CI.
    let schema = |d: &Json| d.get("schema").and_then(Json::as_str).unwrap_or("").to_string();
    let (prev_schema, curr_schema) = (schema(&prev_doc), schema(&curr_doc));
    if prev_schema != curr_schema {
        println!(
            "bench trend: schema changed ({prev_schema:?} → {curr_schema:?}); baseline \
             reset — skipping cycle gate"
        );
        return Ok(());
    }
    let prev = prev_doc.flatten_numbers();
    let curr = curr_doc.flatten_numbers();

    // Counters and cycles print without a fraction; rates keep theirs.
    let fmt = |v: f64| {
        if v.fract() == 0.0 && v.abs() < 1e15 {
            format!("{v:.0}")
        } else {
            format!("{v:.3}")
        }
    };

    let mut t = Table::new(&["Metric", "Prev", "Curr", "Δ%", "Flag"])
        .align(0, Align::Left)
        .align(4, Align::Left);
    let mut regressions: Vec<String> = Vec::new();
    for (key, curr_v) in &curr {
        let Some(prev_v) = prev.get(key) else {
            t.row(&[key.clone(), "-".into(), fmt(*curr_v), "-".into(), "new".into()]);
            continue;
        };
        let delta_pct = if *prev_v == 0.0 {
            if *curr_v == 0.0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (curr_v - prev_v) / prev_v.abs() * 100.0
        };
        let gated = key.ends_with("cycles");
        let regressed = gated && delta_pct > threshold;
        if regressed {
            regressions.push(format!(
                "{key} {} → {} ({delta_pct:+.1}%)",
                fmt(*prev_v),
                fmt(*curr_v)
            ));
        }
        let delta_txt = if delta_pct.is_infinite() {
            "+inf".to_string()
        } else {
            format!("{delta_pct:+.1}")
        };
        let flag = if regressed { "REGRESSED" } else { "" };
        t.row(&[key.clone(), fmt(*prev_v), fmt(*curr_v), delta_txt, flag.into()]);
    }
    for key in prev.keys().filter(|k| !curr.contains_key(*k)) {
        t.row(&[key.clone(), fmt(prev[key]), "-".into(), "-".into(), "dropped".into()]);
    }
    println!("bench trend: {prev_path} → {curr_path} (threshold {threshold}% on *cycles metrics)");
    println!("{}", t.to_text());

    if regressions.is_empty() {
        println!("no cycle regressions above {threshold}%");
        Ok(())
    } else if args.has("fail-on-regress") {
        Err(format!(
            "{} cycle regression(s) above {threshold}%:\n  {}",
            regressions.len(),
            regressions.join("\n  ")
        ))
    } else {
        println!(
            "{} cycle regression(s) above {threshold}% (advisory; --fail-on-regress gates):",
            regressions.len()
        );
        for r in &regressions {
            println!("  {r}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn help_succeeds() {
        assert_eq!(cli_main(argv(&["help"])), 0);
        assert_eq!(cli_main(argv(&[])), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(cli_main(argv(&["frobnicate"])), 2);
    }

    #[test]
    fn inspect_table2_table3_ccp_succeed() {
        assert_eq!(cli_main(argv(&["inspect"])), 0);
        assert_eq!(cli_main(argv(&["table2", "--tiles", "1,4"])), 0);
        assert_eq!(cli_main(argv(&["table3"])), 0);
        assert_eq!(cli_main(argv(&["ccp"])), 0);
        assert_eq!(cli_main(argv(&["ablation", "--tiles", "4"])), 0);
    }

    #[test]
    fn extension_subcommands_succeed() {
        assert_eq!(cli_main(argv(&["tune", "--m", "128", "--n", "128", "--k", "512"])), 0);
        // A problem whose operands exceed the simulated DDR is an error
        // (exit 2), never a panic in the search.
        assert_eq!(
            cli_main(argv(&["tune", "--m", "40000", "--n", "40000", "--k", "40000"])),
            2
        );
        assert_eq!(cli_main(argv(&["energy", "--tiles", "4"])), 0);
        assert_eq!(cli_main(argv(&["noc", "--tiles", "16"])), 0);
        // noc beyond the array is an error.
        assert_eq!(cli_main(argv(&["noc", "--tiles", "401"])), 2);
    }

    #[test]
    fn plan_subcommand_succeeds_and_validates() {
        assert_eq!(cli_main(argv(&["plan"])), 0);
        assert_eq!(
            cli_main(argv(&["plan", "--m", "100", "--n", "37", "--k", "513", "--tiles", "4"])),
            0
        );
        assert_eq!(cli_main(argv(&["plan", "--precision", "i16"])), 0);
        assert_eq!(cli_main(argv(&["plan", "--prepacked", "--count-packing"])), 0);
        // Streaming pricing: same validation surface, no step vector
        // (debug builds also assert streaming == materialized cost).
        assert_eq!(cli_main(argv(&["plan", "--cost-only"])), 0);
        assert_eq!(
            cli_main(argv(&[
                "plan", "--cost-only", "--m", "100", "--n", "37", "--k", "513", "--tiles",
                "4", "--precision", "bf16",
            ])),
            0
        );
        assert_eq!(
            cli_main(argv(&["plan", "--cost-only", "--prepacked", "--count-packing"])),
            0
        );
        assert_eq!(cli_main(argv(&["plan", "--cost-only", "--kc", "8192"])), 2);
        // Validation consistent with the other subcommands: bad
        // precision, zero dims, tile overcommit and an infeasible CCP
        // are errors, not panics.
        assert_eq!(cli_main(argv(&["plan", "--precision", "fp64"])), 2);
        assert_eq!(cli_main(argv(&["plan", "--m", "0"])), 2);
        assert_eq!(cli_main(argv(&["plan", "--tiles", "401"])), 2);
        assert_eq!(cli_main(argv(&["plan", "--kc", "8192"])), 2);
        // 2-byte elements: the u8-feasible kc=2048 Br panel no longer fits.
        assert_eq!(cli_main(argv(&["plan", "--precision", "i16", "--kc", "2048"])), 2);
    }

    #[test]
    fn precision_subcommand_succeeds() {
        assert_eq!(cli_main(argv(&["precision", "--tiles", "4"])), 0);
        assert_eq!(cli_main(argv(&["precision", "--budget", "1e-4"])), 0);
        // Garbage budget is a parse error, not a panic.
        assert_eq!(cli_main(argv(&["precision", "--budget", "tight"])), 2);
    }

    #[test]
    fn cluster_subcommand_succeeds_and_validates() {
        assert_eq!(cli_main(argv(&["cluster", "--devices", "1,2", "--tiles", "4"])), 0);
        assert_eq!(
            cli_main(argv(&["cluster", "--devices", "2", "--fabric", "cxl"])),
            0
        );
        // Unknown fabric and infeasible tile budget are errors, not panics.
        assert_eq!(cli_main(argv(&["cluster", "--fabric", "smoke-signals"])), 2);
        assert_eq!(cli_main(argv(&["cluster", "--devices", "2", "--tiles", "500"])), 2);
    }

    #[test]
    fn cluster_faults_replan_succeeds_and_validates() {
        // Quarantine one of four devices plus tile attrition and a link
        // degrade; the recovery summary prints after the scaling table.
        assert_eq!(
            cli_main(argv(&[
                "cluster", "--devices", "1,2,4", "--tiles", "4", "--faults",
                "device:1@0,tiles:0:2@0,link:50@0",
            ])),
            0
        );
        // Malformed specs and a fully-quarantined pool are errors.
        assert_eq!(
            cli_main(argv(&["cluster", "--devices", "2", "--faults", "meteor:1@0"])),
            2
        );
        assert_eq!(
            cli_main(argv(&[
                "cluster", "--devices", "2", "--tiles", "4", "--faults",
                "device:0@0,device:1@0",
            ])),
            2
        );
    }

    #[test]
    fn serve_faults_inject_and_validate() {
        // A transient fault mid-trace: the run completes and reports.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "8", "--batch", "2", "--tiles", "2", "--rate",
                "100000", "--slo-ms", "200", "--faults", "transient:1@0",
            ])),
            0
        );
        // A device loss on the threads engine: still deterministic.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--engine", "threads", "--requests", "8", "--batch", "2",
                "--workers", "1", "--tiles", "2", "--rate", "100000", "--slo-ms", "200",
                "--faults", "device:1@100",
            ])),
            0
        );
        // Bad specs are usage errors; the wall-clock coordinator
        // refuses the flag outright.
        assert_eq!(
            cli_main(argv(&["serve", "--requests", "2", "--faults", "device:@"])),
            2
        );
        assert_eq!(
            cli_main(argv(&[
                "serve", "--engine", "coordinator", "--requests", "2", "--faults",
                "transient:1@0",
            ])),
            2
        );
    }

    #[test]
    fn serve_runtime_engine_succeeds() {
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "6", "--batch", "2", "--tiles", "2", "--rate",
                "100000", "--mix", "u8:3,i16:1", "--cache-mb", "32", "--slo-ms", "200",
            ])),
            0
        );
    }

    #[test]
    fn serve_new_arrival_families_succeed() {
        for family in ["pareto", "diurnal"] {
            assert_eq!(
                cli_main(argv(&[
                    "serve", "--requests", "6", "--batch", "2", "--tiles", "2", "--rate",
                    "100000", "--slo-ms", "200", "--arrival", family,
                ])),
                0
            );
        }
        // The bursty family honours --burst; a sub-unit ratio is a
        // usage error, not a silently clamped run.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "4", "--batch", "2", "--tiles", "2", "--rate",
                "100000", "--slo-ms", "200", "--arrival", "bursty", "--burst", "8",
            ])),
            0
        );
        assert_eq!(
            cli_main(argv(&["serve", "--requests", "2", "--burst", "0.5"])),
            2
        );
    }

    #[test]
    fn serve_multi_tenant_succeeds_and_validates() {
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "24", "--batch", "2", "--tiles", "2",
                "--offered-load", "100000", "--tenants",
                "gold:1:3:200,free:3:1:200",
            ])),
            0
        );
        // Malformed tenant specs and degenerate rates are errors.
        assert_eq!(
            cli_main(argv(&["serve", "--requests", "2", "--tenants", "gold:1:3"])),
            2
        );
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "2", "--tenants", "gold:1:3:200",
                "--offered-load", "0",
            ])),
            2
        );
    }

    #[test]
    fn serve_threads_engine_succeeds() {
        // The pooled deterministic runtime: same report surface as
        // --engine runtime, numerics on the host pool.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--engine", "threads", "--requests", "4", "--batch", "2",
                "--workers", "1", "--tiles", "2", "--rate", "100000",
            ])),
            0
        );
        // Multi-worker pool and auto sizing (--workers 0) also serve.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--engine", "threads", "--requests", "4", "--batch", "2",
                "--workers", "3", "--tiles", "2", "--rate", "100000",
            ])),
            0
        );
    }

    #[test]
    fn serve_pack_parallel_and_fanout_succeed() {
        // --pack-parallel on the threads engine: parallel pack slices,
        // same verification surface.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--engine", "threads", "--requests", "4", "--batch", "2",
                "--workers", "2", "--tiles", "2", "--rate", "100000", "--pack-parallel",
            ])),
            0
        );
        // --fanout with a multi-tenant trace: distinct-tenant batches
        // run concurrently, same report surface.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "12", "--batch", "2", "--tiles", "2",
                "--offered-load", "100000", "--workers", "2", "--fanout",
                "--tenants", "gold:1:3:200,free:3:1:200",
            ])),
            0
        );
    }

    #[test]
    fn serve_coordinator_engine_succeeds() {
        // The wall-clock router + worker-pool topology demo.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--engine", "coordinator", "--requests", "4", "--batch", "2",
                "--workers", "1", "--tiles", "2", "--rate", "100000",
            ])),
            0
        );
    }

    #[test]
    fn serve_rejects_bad_engine_and_mix() {
        assert_eq!(cli_main(argv(&["serve", "--engine", "warp"])), 2);
        assert_eq!(cli_main(argv(&["serve", "--requests", "2", "--mix", "fp64:1"])), 2);
        assert_eq!(cli_main(argv(&["serve", "--requests", "2", "--arrivals", "nope"])), 2);
        // Degenerate knobs are usage errors, not assertion panics or
        // silent reject-everything runs.
        assert_eq!(cli_main(argv(&["serve", "--requests", "2", "--devices", "0"])), 2);
        assert_eq!(cli_main(argv(&["serve", "--requests", "2", "--batch", "0"])), 2);
        assert_eq!(cli_main(argv(&["serve", "--requests", "2", "--slo-ms", "0"])), 2);
        assert_eq!(cli_main(argv(&["serve", "--requests", "2", "--cache-mb", "-1"])), 2);
        assert_eq!(
            cli_main(argv(&["serve", "--requests", "2", "--plan-cache-mb", "-1"])),
            2
        );
    }

    #[test]
    fn serve_plan_cache_off_still_serves() {
        // --plan-cache-mb 0 is the re-lower-per-batch baseline, not an
        // error: every request must still be answered.
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "4", "--batch", "2", "--tiles", "2", "--rate",
                "100000", "--plan-cache-mb", "0", "--slo-ms", "200",
            ])),
            0
        );
    }

    #[test]
    fn gemm_small_roundtrip() {
        assert_eq!(
            cli_main(argv(&["gemm", "--m", "32", "--n", "24", "--k", "40", "--tiles", "3",
                            "--mc", "16", "--nc", "16", "--kc", "32"])),
            0
        );
    }

    #[test]
    fn gemm_threads_engine_roundtrip_and_validates() {
        // The pooled engine passes the same naive-oracle verification
        // (exit 0 requires max |Δ| == 0), across a ragged shape.
        assert_eq!(
            cli_main(argv(&["gemm", "--m", "37", "--n", "29", "--k", "70", "--tiles", "3",
                            "--mc", "16", "--nc", "16", "--kc", "32",
                            "--engine", "threads", "--workers", "4"])),
            0
        );
        // --workers 0 sizes the pool from the environment/machine.
        assert_eq!(
            cli_main(argv(&["gemm", "--m", "16", "--n", "16", "--k", "32", "--tiles", "2",
                            "--mc", "16", "--nc", "16", "--kc", "32",
                            "--engine", "threads", "--workers", "0"])),
            0
        );
        // --pack-parallel splits pack steps across the pool; the naive
        // oracle still requires bit-exact output for exit 0.
        assert_eq!(
            cli_main(argv(&["gemm", "--m", "37", "--n", "29", "--k", "70", "--tiles", "3",
                            "--mc", "16", "--nc", "16", "--kc", "32",
                            "--engine", "threads", "--workers", "4", "--pack-parallel"])),
            0
        );
        // Unknown engines are usage errors for gemm and plan alike.
        assert_eq!(cli_main(argv(&["gemm", "--engine", "warp"])), 2);
        assert_eq!(cli_main(argv(&["plan", "--engine", "warp"])), 2);
        assert_eq!(cli_main(argv(&["plan", "--engine", "threads"])), 0);
    }

    #[test]
    fn bad_option_reports_error() {
        assert_eq!(cli_main(argv(&["table2", "--tiles", "xyz"])), 2);
        assert_eq!(cli_main(argv(&["--no-such-flag"])), 2);
    }

    use crate::util::json::Json;

    fn tmp_path(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("versal_gemm_cli_{}_{name}", std::process::id()))
    }

    #[test]
    fn plan_trace_out_writes_chrome_json() {
        let path = tmp_path("plan_trace.json");
        let p = path.to_str().unwrap();
        assert_eq!(
            cli_main(argv(&[
                "plan", "--m", "100", "--n", "37", "--k", "513", "--tiles", "4",
                "--trace-out", p,
            ])),
            0
        );
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ns"));
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert!(
            events
                .iter()
                .any(|e| e.get("ph").and_then(Json::as_str) == Some("X")),
            "plan trace must contain complete (X) spans"
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn serve_trace_out_writes_chrome_json() {
        let path = tmp_path("serve_trace.json");
        let p = path.to_str().unwrap();
        assert_eq!(
            cli_main(argv(&[
                "serve", "--requests", "6", "--batch", "2", "--tiles", "2", "--rate",
                "100000", "--slo-ms", "200", "--trace-out", p,
            ])),
            0
        );
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let names: Vec<String> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(Json::as_str).map(String::from))
            .collect();
        for want in ["admitted", "batch formed", "compute", "completed", "queue depth"] {
            assert!(
                names.iter().any(|n| n == want),
                "serve trace must contain a {want:?} event"
            );
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bench_trend_diffs_and_gates() {
        let prev = tmp_path("trend_prev.json");
        let curr = tmp_path("trend_curr.json");
        std::fs::write(
            &prev,
            "{\"rows\":[{\"compute_cycles\":1000,\"pack_cycles\":100,\"requests\":5}]}",
        )
        .unwrap();
        std::fs::write(
            &curr,
            "{\"rows\":[{\"compute_cycles\":1200,\"pack_cycles\":100,\"requests\":7}]}",
        )
        .unwrap();
        let (p, c) = (prev.to_str().unwrap(), curr.to_str().unwrap());
        // Advisory by default: the 20% compute regression prints, exit 0.
        assert_eq!(cli_main(argv(&["bench-trend", p, c])), 0);
        // --fail-on-regress turns it into exit 2.
        assert_eq!(cli_main(argv(&["bench-trend", p, c, "--fail-on-regress"])), 2);
        // A generous threshold passes even when gated; non-cycle growth
        // (requests 5 → 7) never gates.
        assert_eq!(
            cli_main(argv(&[
                "bench-trend", p, c, "--threshold", "25", "--fail-on-regress",
            ])),
            0
        );
        // Identical artifacts never regress.
        assert_eq!(cli_main(argv(&["bench-trend", p, p, "--fail-on-regress"])), 0);
        // A NaN threshold is a usage error, not a vacuous pass.
        assert_eq!(cli_main(argv(&["bench-trend", p, p, "--threshold", "nan"])), 2);
        std::fs::remove_file(&prev).ok();
        std::fs::remove_file(&curr).ok();
    }

    #[test]
    fn bench_trend_schema_change_resets_baseline() {
        // A schema bump makes row-by-row comparison meaningless; the
        // trend run reports the reset and exits 0 even under
        // --fail-on-regress and even when the numbers regressed.
        let prev = tmp_path("trend_schema_prev.json");
        let curr = tmp_path("trend_schema_curr.json");
        std::fs::write(&prev, "{\"rows\":[{\"compute_cycles\":1000}]}").unwrap();
        std::fs::write(
            &curr,
            "{\"schema\":\"serving-v2\",\"rows\":[{\"compute_cycles\":9000}]}",
        )
        .unwrap();
        let (p, c) = (prev.to_str().unwrap(), curr.to_str().unwrap());
        assert_eq!(cli_main(argv(&["bench-trend", p, c, "--fail-on-regress"])), 0);
        // Same schema tag on both sides gates as usual.
        assert_eq!(cli_main(argv(&["bench-trend", c, c, "--fail-on-regress"])), 0);
        std::fs::remove_file(&prev).ok();
        std::fs::remove_file(&curr).ok();
    }

    #[test]
    fn bench_trend_validates_usage() {
        // Missing operands and unreadable / malformed artifacts are
        // errors (exit 2), never panics.
        assert_eq!(cli_main(argv(&["bench-trend"])), 2);
        assert_eq!(
            cli_main(argv(&["bench-trend", "/no/such/prev.json", "/no/such/curr.json"])),
            2
        );
        let bad = tmp_path("trend_bad.json");
        std::fs::write(&bad, "{not json").unwrap();
        let b = bad.to_str().unwrap();
        assert_eq!(cli_main(argv(&["bench-trend", b, b])), 2);
        std::fs::remove_file(&bad).ok();
    }
}
