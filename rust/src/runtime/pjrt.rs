//! PJRT execution engine.
//!
//! Wraps the `xla` crate: one CPU client, a cache of compiled executables
//! keyed by [`ArtifactId`], and typed helpers for the artifact signatures.
//! Compilation happens once per artifact per engine (the AOT property);
//! execution is allocation-light and safe to call from the serving loop.

use super::artifact::{ArtifactId, ArtifactRegistry};
use crate::gemm::{MatI32, MatU8};
use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// A PJRT engine bound to an artifact registry.
pub struct Engine {
    client: xla::PjRtClient,
    registry: ArtifactRegistry,
    cache: HashMap<ArtifactId, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Create a CPU engine over the given registry.
    pub fn new(registry: ArtifactRegistry) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, registry, cache: HashMap::new() })
    }

    /// Create an engine over the default artifacts directory.
    pub fn default_location() -> Result<Engine> {
        Engine::new(ArtifactRegistry::default_location())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Load + compile an artifact (cached).
    pub fn load(&mut self, id: ArtifactId) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&id) {
            let path = self.registry.path(id);
            if !path.is_file() {
                bail!(
                    "artifact {:?} not found at {} — run `make artifacts` first",
                    id,
                    path.display()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {:?}", id))?;
            self.cache.insert(id, exe);
        }
        Ok(&self.cache[&id])
    }

    /// Execute a GEMM artifact: C = A·B (u8 inputs, i32 result).
    /// Shapes must match the artifact's baked signature.
    pub fn gemm_u8(&mut self, id: ArtifactId, a: &MatU8, b: &MatU8) -> Result<MatI32> {
        let (m, n) = (a.rows, b.cols);
        // u8 is not a NativeType in xla 0.1.6; build the literals from raw
        // bytes (u8 data is its own byte representation).
        let la = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[a.rows, a.cols],
            &a.data,
        )
        .context("creating A literal")?;
        let lb = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[b.rows, b.cols],
            &b.data,
        )
        .context("creating B literal")?;
        let exe = self.load(id)?;
        let result = exe.execute::<xla::Literal>(&[la, lb]).context("executing GEMM artifact")?;
        let tuple = result[0][0].to_literal_sync().context("fetching result literal")?;
        // aot.py lowers with return_tuple=True ⇒ 1-tuple.
        let out = tuple.to_tuple1().context("unwrapping result tuple")?;
        let values = out.to_vec::<i32>().context("reading i32 result")?;
        if values.len() != m * n {
            bail!("artifact returned {} values, expected {}", values.len(), m * n);
        }
        Ok(MatI32::from_vec(m, n, values))
    }

    /// Execute the MLP artifact: logits = mlp(x), f32\[batch,784\] →
    /// f32\[batch,10\] (batch baked to 8 in the artifact).
    pub fn mlp_forward(&mut self, batch: usize, x: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == batch * 784, "expected {}, got {}", batch * 784, x.len());
        let lx = xla::Literal::vec1(x)
            .reshape(&[batch as i64, 784])
            .context("reshaping MLP input")?;
        let exe = self.load(ArtifactId::MlpU8B8)?;
        let result = exe.execute::<xla::Literal>(&[lx]).context("executing MLP artifact")?;
        let tuple = result[0][0].to_literal_sync()?;
        let out = tuple.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

// NOTE: correctness of these paths against the Rust GEMM engine and the
// Python oracle is covered by `rust/tests/pjrt_integration.rs`, which
// requires `make artifacts` to have run. Unit tests here stay
// artifact-free so `cargo test` works on a clean checkout.
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_gives_actionable_error() {
        let reg = ArtifactRegistry::new("/nonexistent/dir");
        let mut eng = Engine::new(reg).expect("CPU client");
        let e = match eng.load(ArtifactId::GemmU8_64) {
            Ok(_) => panic!("load must fail for a missing artifact"),
            Err(e) => e,
        };
        let msg = format!("{e:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }

    #[test]
    fn cpu_client_reports_platform() {
        let eng = Engine::new(ArtifactRegistry::new("artifacts")).unwrap();
        let p = eng.platform().to_lowercase();
        assert!(p.contains("cpu") || p.contains("host"), "platform {p}");
    }
}
