//! Artifact registry: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! Every artifact is an HLO-text file named `<id>.hlo.txt`. The IDs and
//! their shapes are fixed here and mirrored by `aot.py`; integration
//! tests assert both sides agree.

use std::path::{Path, PathBuf};

/// Known AOT artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ArtifactId {
    /// u8\[64,64\] · u8\[64,64\] → (i32\[64,64\],) through the Pallas blocked
    /// GEMM kernel (micro-kernel + packing schedule in BlockSpec form).
    GemmU8_64,
    /// The paper's Table 2 problem: u8\[256,2048\] · u8\[2048,256\] →
    /// (i32\[256,256\],).
    GemmU8Paper,
    /// Quantised MLP classifier forward at batch 8:
    /// f32\[8,784\] → (f32\[8,10\],) with u8 weights baked in and every
    /// matmul running through the Pallas micro-kernel.
    MlpU8B8,
}

impl ArtifactId {
    pub const ALL: [ArtifactId; 3] =
        [ArtifactId::GemmU8_64, ArtifactId::GemmU8Paper, ArtifactId::MlpU8B8];

    /// File stem (matches `python/compile/aot.py` `ARTIFACTS`).
    pub fn stem(self) -> &'static str {
        match self {
            ArtifactId::GemmU8_64 => "gemm_u8_64",
            ArtifactId::GemmU8Paper => "gemm_u8_paper",
            ArtifactId::MlpU8B8 => "mlp_u8_b8",
        }
    }

    pub fn file_name(self) -> String {
        format!("{}.hlo.txt", self.stem())
    }
}

/// Default artifacts directory: `$VERSAL_ARTIFACTS_DIR` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("VERSAL_ARTIFACTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Registry rooted at a directory.
#[derive(Debug, Clone)]
pub struct ArtifactRegistry {
    root: PathBuf,
}

impl ArtifactRegistry {
    pub fn new(root: impl Into<PathBuf>) -> ArtifactRegistry {
        ArtifactRegistry { root: root.into() }
    }

    pub fn default_location() -> ArtifactRegistry {
        ArtifactRegistry::new(artifacts_dir())
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn path(&self, id: ArtifactId) -> PathBuf {
        self.root.join(id.file_name())
    }

    pub fn exists(&self, id: ArtifactId) -> bool {
        self.path(id).is_file()
    }

    /// IDs that are missing on disk (for a helpful `make artifacts` hint).
    pub fn missing(&self) -> Vec<ArtifactId> {
        ArtifactId::ALL.iter().copied().filter(|&id| !self.exists(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stems_are_unique_and_stable() {
        let stems: Vec<&str> = ArtifactId::ALL.iter().map(|a| a.stem()).collect();
        let mut uniq = stems.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), stems.len());
        assert_eq!(ArtifactId::GemmU8_64.file_name(), "gemm_u8_64.hlo.txt");
    }

    #[test]
    fn registry_paths_and_missing() {
        let tmp = std::env::temp_dir().join("versal_artifact_test");
        let _ = std::fs::create_dir_all(&tmp);
        let reg = ArtifactRegistry::new(&tmp);
        assert!(reg.path(ArtifactId::MlpU8B8).ends_with("mlp_u8_b8.hlo.txt"));
        // Create one artifact; the other two must show as missing.
        std::fs::write(reg.path(ArtifactId::GemmU8_64), "dummy").unwrap();
        let missing = reg.missing();
        assert!(!missing.contains(&ArtifactId::GemmU8_64));
        assert!(missing.contains(&ArtifactId::GemmU8Paper));
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
