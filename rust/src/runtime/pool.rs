//! Vendored work-stealing thread pool — the host-side execution engine
//! behind `--engine threads`.
//!
//! Two surfaces, one scheduler discipline:
//!
//! - [`ThreadPool::run`] executes a batch of **borrowing** closures on
//!   scoped worker threads and returns their results **in task-index
//!   order** (never completion order). This is the GEMM drivers' entry
//!   point: per-block numerics tasks borrow the operand matrices and
//!   disjoint output bands, and the index-ordered return is what pins
//!   the deterministic reduction the cross-engine parity battery
//!   asserts (`tests/engine_parity.rs`).
//! - [`ThreadPool::spawn`] + [`ThreadPool::shutdown`] manage a crew of
//!   **resident** workers for `'static` fire-and-forget jobs (future
//!   background packing / prefetch). Shutdown is graceful: jobs still
//!   queued at shutdown time are drained, never dropped.
//!
//! Scheduling is work-stealing in both cases: each worker owns a deque,
//! pops its own front, and steals from a victim's back when it runs
//! dry, so uneven task sizes rebalance without a central dispatcher.
//! The pool is dependency-free (`std` only — no crossbeam, no rayon)
//! and contains no `unsafe`.
//!
//! A panicking task never hangs the pool: the panic is caught on the
//! worker, recorded, and surfaced as an error from [`ThreadPool::run`]
//! (or [`ThreadPool::shutdown`]) after every sibling task finished.

use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Environment variable overriding the default worker count of
/// [`ThreadPool::from_env`] (the CI parity matrix sets it to 1/2/8).
pub const POOL_SIZE_ENV: &str = "PALLAS_POOL_SIZE";

/// A fire-and-forget job for the resident crew.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// Lock a mutex, ignoring poisoning: every task body runs under
/// `catch_unwind`, so a poisoned lock only means a *caught* panic
/// happened on another worker — the protected data (a deque of indices
/// or a result slot) is still structurally valid.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Render a caught panic payload for the error message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Pop a task index: own deque first (front), then steal from the other
/// workers' backs, scanning round-robin from the next worker up.
fn grab(queues: &[Mutex<VecDeque<usize>>], me: usize) -> Option<usize> {
    if let Some(i) = lock_ignore_poison(&queues[me]).pop_front() {
        return Some(i);
    }
    let w = queues.len();
    for d in 1..w {
        if let Some(i) = lock_ignore_poison(&queues[(me + d) % w]).pop_back() {
            return Some(i);
        }
    }
    None
}

/// Shared state of the resident (`'static` job) crew.
struct ResidentShared {
    /// Single injector queue — resident jobs are fire-and-forget, so
    /// FIFO fairness matters more than locality here.
    queue: Mutex<VecDeque<Job>>,
    /// Wakes idle workers on new work or shutdown.
    cv: Condvar,
    /// Set once by [`ThreadPool::shutdown`]; workers drain the queue and
    /// then exit.
    shutdown: AtomicBool,
    /// Jobs that ran to completion (including panicked ones).
    completed: AtomicUsize,
    /// Jobs whose closure panicked (caught, counted, surfaced at
    /// shutdown).
    panicked: AtomicUsize,
}

/// The resident crew: shared state + join handles.
struct Resident {
    shared: Arc<ResidentShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

fn resident_worker(shared: Arc<ResidentShared>) {
    loop {
        let job = {
            let mut q = lock_ignore_poison(&shared.queue);
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    break None;
                }
                // Timed wait: belt-and-braces against a lost wakeup —
                // correctness never depends on the notify arriving.
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, Duration::from_millis(10))
                    .unwrap_or_else(|e| e.into_inner());
                q = guard;
            }
        };
        match job {
            Some(j) => {
                if catch_unwind(AssertUnwindSafe(j)).is_err() {
                    shared.panicked.fetch_add(1, Ordering::SeqCst);
                }
                shared.completed.fetch_add(1, Ordering::SeqCst);
            }
            None => break,
        }
    }
}

/// A work-stealing host thread pool (see the module docs). Cheap to
/// construct: scoped workers are spawned per [`ThreadPool::run`] call
/// and resident workers lazily on first [`ThreadPool::spawn`].
pub struct ThreadPool {
    workers: usize,
    resident: Mutex<Option<Resident>>,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ThreadPool").field("workers", &self.workers).finish()
    }
}

impl ThreadPool {
    /// A pool of `workers` worker threads. `0` and `1` are valid
    /// degenerate configs: every task runs inline on the calling
    /// thread, in task-index order — the sequential reference the
    /// parity battery compares against.
    pub fn new(workers: usize) -> ThreadPool {
        ThreadPool { workers, resident: Mutex::new(None) }
    }

    /// Worker count from [`POOL_SIZE_ENV`] when set (and parseable),
    /// otherwise the host's available parallelism.
    pub fn from_env() -> ThreadPool {
        let workers = std::env::var(POOL_SIZE_ENV)
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            });
        ThreadPool::new(workers)
    }

    /// The configured worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Execute every task and return the results **in task-index
    /// order**, regardless of which worker finished when — the
    /// deterministic reduce order the engines rely on.
    ///
    /// Task indices are dealt round-robin into per-worker deques;
    /// workers pop their own front and steal from a victim's back when
    /// they run dry, so uneven task durations rebalance. With 0 or 1
    /// workers (or a single task) everything runs inline on the caller.
    ///
    /// If any task panics, the panic is caught on its worker, the
    /// remaining tasks still run, and `run` returns an error naming the
    /// first panicking task — it never hangs the join.
    pub fn run<T, F>(&self, tasks: Vec<F>) -> Result<Vec<T>>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = tasks.len();
        if self.workers <= 1 || n <= 1 {
            let mut out = Vec::with_capacity(n);
            for t in tasks {
                out.push(t());
            }
            return Ok(out);
        }
        let w = self.workers.min(n);
        // Deal indices round-robin so early tasks spread across workers.
        let queues: Vec<Mutex<VecDeque<usize>>> =
            (0..w).map(|wi| Mutex::new((wi..n).step_by(w).collect())).collect();
        let slots: Vec<Mutex<Option<F>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let results: Vec<Mutex<Option<std::thread::Result<T>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();

        std::thread::scope(|s| {
            for me in 0..w {
                let queues = &queues;
                let slots = &slots;
                let results = &results;
                s.spawn(move || {
                    while let Some(idx) = grab(queues, me) {
                        if let Some(task) = lock_ignore_poison(&slots[idx]).take() {
                            let r = catch_unwind(AssertUnwindSafe(task));
                            *lock_ignore_poison(&results[idx]) = Some(r);
                        }
                    }
                });
            }
        });

        let mut out = Vec::with_capacity(n);
        for (i, slot) in results.into_iter().enumerate() {
            match slot.into_inner().unwrap_or_else(|e| e.into_inner()) {
                Some(Ok(v)) => out.push(v),
                Some(Err(p)) => {
                    return Err(anyhow!("pool task {i} panicked: {}", panic_message(&*p)))
                }
                None => return Err(anyhow!("pool task {i} was never executed")),
            }
        }
        Ok(out)
    }

    /// Enqueue a `'static` fire-and-forget job on the resident crew
    /// (spawned lazily on first use). With 0 workers the job runs
    /// inline — the degenerate config stays functional.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        if self.workers == 0 {
            job();
            return;
        }
        let mut guard = lock_ignore_poison(&self.resident);
        let resident = guard.get_or_insert_with(|| {
            let shared = Arc::new(ResidentShared {
                queue: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                shutdown: AtomicBool::new(false),
                completed: AtomicUsize::new(0),
                panicked: AtomicUsize::new(0),
            });
            let handles = (0..self.workers)
                .map(|_| {
                    let shared = Arc::clone(&shared);
                    std::thread::spawn(move || resident_worker(shared))
                })
                .collect();
            Resident { shared, handles }
        });
        lock_ignore_poison(&resident.shared.queue).push_back(Box::new(job));
        resident.shared.cv.notify_one();
    }

    /// Gracefully stop the resident crew: jobs still queued are drained
    /// (never dropped), workers join, and the total completed-job count
    /// is returned. An error reports how many jobs panicked (after the
    /// drain — a panic never hangs the join). Idempotent: with no crew
    /// running this returns `Ok(0)`; a later [`ThreadPool::spawn`]
    /// starts a fresh crew.
    pub fn shutdown(&self) -> Result<usize> {
        let resident = match lock_ignore_poison(&self.resident).take() {
            Some(r) => r,
            None => return Ok(0),
        };
        resident.shared.shutdown.store(true, Ordering::SeqCst);
        resident.shared.cv.notify_all();
        for h in resident.handles {
            let _ = h.join();
        }
        let completed = resident.shared.completed.load(Ordering::SeqCst);
        let panicked = resident.shared.panicked.load(Ordering::SeqCst);
        if panicked > 0 {
            return Err(anyhow!(
                "{panicked} of {completed} resident pool jobs panicked"
            ));
        }
        Ok(completed)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Best-effort graceful drain; panics were already counted.
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_task_index_order() {
        let pool = ThreadPool::new(4);
        // Reverse-sorted sleep times: late indices finish first, yet the
        // result vector must be index-ordered.
        let tasks: Vec<_> = (0..16u64)
            .map(|i| {
                move || {
                    std::thread::sleep(Duration::from_micros((16 - i) * 50));
                    i * i
                }
            })
            .collect();
        let out = pool.run(tasks).unwrap();
        assert_eq!(out, (0..16u64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn work_stealing_picks_up_uneven_chunk_sizes() {
        // 2 workers, tasks dealt round-robin: worker 0 gets all the slow
        // tasks (even indices), worker 1 all the fast ones. Without
        // stealing the slow lane serialises; with stealing every task
        // still completes and the busy counter proves both workers ran
        // tasks from the slow lane's deque.
        let pool = ThreadPool::new(2);
        let ran = AtomicUsize::new(0);
        let tasks: Vec<_> = (0..32)
            .map(|i| {
                let ran = &ran;
                move || {
                    if i % 2 == 0 {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    ran.fetch_add(1, Ordering::SeqCst);
                    i
                }
            })
            .collect();
        let out = pool.run(tasks).unwrap();
        assert_eq!(ran.load(Ordering::SeqCst), 32, "every task executed exactly once");
        assert_eq!(out, (0..32).collect::<Vec<_>>());
    }

    #[test]
    fn panic_propagates_as_error_not_hang() {
        let pool = ThreadPool::new(4);
        let survivors = AtomicUsize::new(0);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8)
            .map(|i| {
                let survivors = &survivors;
                let f: Box<dyn FnOnce() -> usize + Send> = Box::new(move || {
                    if i == 3 {
                        panic!("task {i} exploded");
                    }
                    survivors.fetch_add(1, Ordering::SeqCst);
                    i
                });
                f
            })
            .collect();
        let err = pool.run(tasks).unwrap_err().to_string();
        assert!(err.contains("task 3"), "error names the panicking task: {err}");
        assert!(err.contains("exploded"), "error carries the panic message: {err}");
        // The siblings were not abandoned by the panic.
        assert_eq!(survivors.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn zero_and_one_worker_degenerate_configs_run_inline() {
        for workers in [0, 1] {
            let pool = ThreadPool::new(workers);
            assert_eq!(pool.workers(), workers);
            let out = pool.run((0..5).map(|i| move || i + 1).collect::<Vec<_>>()).unwrap();
            assert_eq!(out, vec![1, 2, 3, 4, 5]);
            // Degenerate spawn runs inline / on a single worker and
            // still drains at shutdown.
            let hits = Arc::new(AtomicUsize::new(0));
            for _ in 0..3 {
                let hits = Arc::clone(&hits);
                pool.spawn(move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.shutdown().unwrap();
            assert_eq!(hits.load(Ordering::SeqCst), 3, "workers={workers}");
        }
    }

    #[test]
    fn empty_and_single_task_batches() {
        let pool = ThreadPool::new(4);
        let empty: Vec<Box<dyn FnOnce() -> u32 + Send>> = Vec::new();
        assert_eq!(pool.run(empty).unwrap(), Vec::<u32>::new());
        assert_eq!(pool.run(vec![|| 42]).unwrap(), vec![42]);
    }

    #[test]
    fn graceful_shutdown_drains_queued_jobs() {
        let pool = ThreadPool::new(2);
        let done = Arc::new(AtomicUsize::new(0));
        // Far more jobs than workers, each slow enough that most are
        // still queued when shutdown is requested.
        for _ in 0..24 {
            let done = Arc::clone(&done);
            pool.spawn(move || {
                std::thread::sleep(Duration::from_millis(1));
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        let completed = pool.shutdown().unwrap();
        assert_eq!(completed, 24, "queued jobs drained, not dropped");
        assert_eq!(done.load(Ordering::SeqCst), 24);
        // Idempotent; and a fresh crew can be started afterwards.
        assert_eq!(pool.shutdown().unwrap(), 0);
        let done2 = Arc::clone(&done);
        pool.spawn(move || {
            done2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(pool.shutdown().unwrap(), 1);
    }

    #[test]
    fn resident_panic_surfaces_at_shutdown() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| panic!("resident job failed"));
        pool.spawn(|| {});
        let err = pool.shutdown().unwrap_err().to_string();
        assert!(err.contains("panicked"), "{err}");
    }

    #[test]
    fn from_env_honors_pool_size_variable() {
        // Set/remove PALLAS_POOL_SIZE around the call; the test runner
        // may run tests concurrently, so use a distinctive value and
        // restore the previous state.
        let prev = std::env::var(POOL_SIZE_ENV).ok();
        std::env::set_var(POOL_SIZE_ENV, "3");
        assert_eq!(ThreadPool::from_env().workers(), 3);
        match prev {
            Some(v) => std::env::set_var(POOL_SIZE_ENV, v),
            None => std::env::remove_var(POOL_SIZE_ENV),
        }
        assert!(ThreadPool::from_env().workers() >= 1);
    }

    #[test]
    fn heavy_reduction_matches_sequential_fold() {
        // A numeric smoke in the pool's own terms: partial sums computed
        // on workers, reduced in task-index order, equal the sequential
        // fold exactly (integer domain).
        let data: Vec<u64> = (0..10_000).map(|i| (i * 2654435761u64) >> 7).collect();
        let chunks: Vec<&[u64]> = data.chunks(613).collect();
        let pool = ThreadPool::new(8);
        let partials = pool
            .run(chunks.iter().map(|ch| move || ch.iter().sum::<u64>()).collect::<Vec<_>>())
            .unwrap();
        let total: u64 = partials.iter().sum();
        assert_eq!(total, data.iter().sum::<u64>());
    }
}
