//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! The build-time Python layer (`python/compile/aot.py`) lowers the JAX/
//! Pallas computations to **HLO text** under `artifacts/`; this module
//! loads them with the `xla` crate (PJRT C API, CPU client), compiles them
//! once, and executes them from the L3 hot path. Python never runs at
//! request time.
//!
//! Interchange is HLO text rather than a serialized `HloModuleProto`
//! because jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §3).
//!
//! The PJRT engine itself is gated behind the `pjrt` cargo feature: the
//! `xla` crate needs network access and a libxla install, neither of
//! which exists in the offline build environment. The artifact registry
//! (pure filesystem) is always available.
//!
//! The module also hosts the host-side execution machinery that is
//! *not* PJRT-specific: [`pool::ThreadPool`], the vendored
//! work-stealing thread pool behind the `--engine threads` CLI seam,
//! and [`arena::PackArena`], the recycled pack-buffer pool behind the
//! zero-allocation GEMM hot loop.

pub mod arena;
mod artifact;
#[cfg(feature = "pjrt")]
mod pjrt;
pub mod pool;

pub use arena::{pack_parallel_from_env, ArenaStats, PackArena, PACK_PARALLEL_ENV};
pub use artifact::{artifacts_dir, ArtifactId, ArtifactRegistry};
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;
pub use pool::ThreadPool;
