//! Pack-buffer arena: recycled backing storage for the GEMM hot loop.
//!
//! The blocked/parallel drivers allocate a fresh `PackedA`/`PackedB`
//! backing `Vec` on every block iteration of the plan walk — in a
//! serving steady state that is thousands of short-lived heap
//! allocations per second for buffers whose sizes repeat exactly
//! (a plan has a handful of distinct pack extents). [`PackArena`]
//! breaks that churn: buffers are checked out per element type from
//! power-of-two size-class free lists and recycled on `Release`, so
//! after the first block of the first call the walk reuses warm
//! capacity and performs **zero heap allocation** for packing
//! (pinned by `tests/serving_alloc.rs`).
//!
//! Determinism is free by construction: a checkout clears and
//! re-zeroes the buffer to the exact requested length (`resize(n,
//! T::default())`), which is element-for-element what the cold
//! `vec![T::default(); n]` produced — the zero-padded edge-panel
//! invariant of [`crate::gemm::packing`] is preserved bit-for-bit.
//!
//! The arena is `Send + Sync` (per-type mutexed free lists, atomic
//! counters) and shared as an `Arc` between the serving backend, the
//! engines and — under parallel packing — the pool workers.

use crate::gemm::precision::Bf16;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Environment variable enabling parallel packing (`1` turns the
/// μ-panel-sliced pack path on wherever a host pool is attached) —
/// the CI matrix axis next to [`super::pool::POOL_SIZE_ENV`].
pub const PACK_PARALLEL_ENV: &str = "PALLAS_PACK_PARALLEL";

/// Whether [`PACK_PARALLEL_ENV`] asks for parallel packing.
pub fn pack_parallel_from_env() -> bool {
    matches!(std::env::var(PACK_PARALLEL_ENV).as_deref(), Ok("1") | Ok("true") | Ok("on"))
}

/// Upper bound on free buffers retained per size class (per element
/// type): beyond this, recycled buffers are dropped. A plan keeps at
/// most a few packs alive at once, so the bound only matters when a
/// caller recycles far more than it checks out.
const MAX_FREE_PER_CLASS: usize = 32;

/// Size classes cover capacities up to `2^(N_CLASSES-1)` elements;
/// larger buffers are still served (exact capacity) but not pooled
/// beyond the top class.
const N_CLASSES: usize = 40;

fn class_of(n: usize) -> usize {
    // ceil(log2(n)) clamped to the class table; class c holds buffers
    // with capacity in (2^(c-1), 2^c].
    (usize::BITS - n.max(1).next_power_of_two().leading_zeros() - 1).min(N_CLASSES as u32 - 1)
        as usize
}

/// One element type's free lists, bucketed by floor-log2 capacity.
struct FreeLists<T> {
    classes: Mutex<Vec<Vec<Vec<T>>>>,
}

impl<T> Default for FreeLists<T> {
    fn default() -> FreeLists<T> {
        FreeLists { classes: Mutex::new((0..N_CLASSES).map(|_| Vec::new()).collect()) }
    }
}

impl<T: Copy + Default> FreeLists<T> {
    /// A buffer of exactly `n` zeroed elements: recycled capacity when a
    /// large-enough buffer is free, a fresh allocation (capacity rounded
    /// up to the class size) otherwise. Returns `(buf, recycled?)`.
    fn checkout(&self, n: usize) -> (Vec<T>, bool) {
        let want = class_of(n);
        let mut classes = lock_ignore_poison(&self.classes);
        for c in want..N_CLASSES {
            if let Some(mut buf) = classes[c].pop() {
                drop(classes);
                debug_assert!(buf.capacity() >= n, "class {c} buffer too small for {n}");
                buf.clear();
                buf.resize(n, T::default());
                return (buf, true);
            }
        }
        drop(classes);
        let mut buf = Vec::with_capacity(n.max(1).next_power_of_two());
        buf.resize(n, T::default());
        (buf, false)
    }

    /// Return a buffer's capacity to its size class (dropped when the
    /// class is full or the buffer has no capacity).
    fn recycle(&self, buf: Vec<T>) -> bool {
        if buf.capacity() == 0 {
            return false;
        }
        // floor(log2(capacity)): a buffer sits in the largest class
        // whose checkout demand it can always satisfy.
        let c = ((usize::BITS - 1 - buf.capacity().leading_zeros()) as usize)
            .min(N_CLASSES - 1);
        let mut classes = lock_ignore_poison(&self.classes);
        if classes[c].len() < MAX_FREE_PER_CLASS {
            classes[c].push(buf);
            true
        } else {
            false
        }
    }
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// An element type the arena can pool. Sealed to the four precisions of
/// the mixed-precision suite — exactly the types [`crate::gemm::packing`]
/// packs. The methods are routing plumbing; use [`PackArena::checkout`]
/// and [`PackArena::recycle`].
pub trait ArenaElement: Copy + Default + Send + 'static {
    /// Checkout from this element type's free lists inside the arena.
    #[doc(hidden)]
    fn arena_checkout(arena: &PackArena, n: usize) -> (Vec<Self>, bool)
    where
        Self: Sized;

    /// Recycle into this element type's free lists inside the arena.
    #[doc(hidden)]
    fn arena_recycle(arena: &PackArena, buf: Vec<Self>) -> bool
    where
        Self: Sized;
}

macro_rules! arena_element {
    ($ty:ty, $field:ident) => {
        impl ArenaElement for $ty {
            fn arena_checkout(arena: &PackArena, n: usize) -> (Vec<$ty>, bool) {
                arena.$field.checkout(n)
            }
            fn arena_recycle(arena: &PackArena, buf: Vec<$ty>) -> bool {
                arena.$field.recycle(buf)
            }
        }
    };
}

arena_element!(u8, pool_u8);
arena_element!(i8, pool_i8);
arena_element!(i16, pool_i16);
arena_element!(Bf16, pool_bf16);

/// Checkout/recycle counters — the warm-path witness
/// (`fresh == 0` over a warm interval means the steady state allocated
/// nothing for packing).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ArenaStats {
    /// Buffers handed out (recycled + fresh).
    pub checkouts: u64,
    /// Checkouts served from a free list (no heap allocation).
    pub recycled: u64,
    /// Checkouts that had to allocate a fresh backing buffer.
    pub fresh: u64,
    /// Buffers returned to a free list.
    pub returned: u64,
}

/// Recycled pack-buffer pool: per-precision, power-of-two size-class
/// free lists behind [`crate::gemm::packing::pack_a_in`] /
/// [`crate::gemm::packing::pack_b_in`] and the engines' plan walks.
/// See the module docs for the lifecycle and determinism argument.
#[derive(Default)]
pub struct PackArena {
    pool_u8: FreeLists<u8>,
    pool_i8: FreeLists<i8>,
    pool_i16: FreeLists<i16>,
    pool_bf16: FreeLists<Bf16>,
    checkouts: AtomicU64,
    recycled: AtomicU64,
    fresh: AtomicU64,
    returned: AtomicU64,
}

impl PackArena {
    /// An empty arena (free lists fill as buffers are recycled).
    pub fn new() -> PackArena {
        PackArena::default()
    }

    /// A zeroed buffer of exactly `n` elements: warm capacity when a
    /// free buffer of the right class exists, a fresh allocation
    /// otherwise. Element-for-element identical to
    /// `vec![T::default(); n]`.
    pub fn checkout<T: ArenaElement>(&self, n: usize) -> Vec<T> {
        let (buf, was_recycled) = T::arena_checkout(self, n);
        self.checkouts.fetch_add(1, Ordering::Relaxed);
        if was_recycled {
            self.recycled.fetch_add(1, Ordering::Relaxed);
        } else {
            self.fresh.fetch_add(1, Ordering::Relaxed);
        }
        buf
    }

    /// Hand a buffer's capacity back for reuse. Dropping a buffer
    /// instead of recycling it is always safe — the arena is an
    /// optimisation, never an obligation.
    pub fn recycle<T: ArenaElement>(&self, buf: Vec<T>) {
        if T::arena_recycle(self, buf) {
            self.returned.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Snapshot of the counters (Relaxed reads — exact once concurrent
    /// checkouts have quiesced).
    pub fn stats(&self) -> ArenaStats {
        ArenaStats {
            checkouts: self.checkouts.load(Ordering::Relaxed),
            recycled: self.recycled.load(Ordering::Relaxed),
            fresh: self.fresh.load(Ordering::Relaxed),
            returned: self.returned.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkout_is_zeroed_and_exact_length() {
        let arena = PackArena::new();
        let mut v: Vec<u8> = arena.checkout(100);
        assert_eq!(v.len(), 100);
        assert!(v.iter().all(|&x| x == 0));
        v.iter_mut().for_each(|x| *x = 0xAB);
        arena.recycle(v);
        // The recycled buffer comes back zeroed at the new length.
        let v2: Vec<u8> = arena.checkout(64);
        assert_eq!(v2.len(), 64);
        assert!(v2.iter().all(|&x| x == 0), "recycled buffer must be re-zeroed");
    }

    #[test]
    fn warm_checkout_recycles_instead_of_allocating() {
        let arena = PackArena::new();
        let v: Vec<i16> = arena.checkout(1000);
        let cap = v.capacity();
        arena.recycle(v);
        let v2: Vec<i16> = arena.checkout(900);
        assert_eq!(v2.capacity(), cap, "same backing buffer served again");
        let s = arena.stats();
        assert_eq!((s.checkouts, s.recycled, s.fresh, s.returned), (2, 1, 1, 1));
    }

    #[test]
    fn larger_request_after_recycle_allocates_fresh() {
        let arena = PackArena::new();
        let v: Vec<u8> = arena.checkout(64); // capacity 64, class 6
        arena.recycle(v);
        // 65 needs class 7; the class-6 buffer cannot serve it.
        let v2: Vec<u8> = arena.checkout(65);
        assert!(v2.capacity() >= 65);
        assert_eq!(arena.stats().fresh, 2);
    }

    #[test]
    fn per_type_pools_are_independent() {
        let arena = PackArena::new();
        let v: Vec<u8> = arena.checkout(256);
        arena.recycle(v);
        // An i8 checkout of the same size must not see the u8 buffer.
        let _w: Vec<i8> = arena.checkout(256);
        assert_eq!(arena.stats().recycled, 0);
        let _b: Vec<Bf16> = arena.checkout(8);
        assert_eq!(arena.stats().fresh, 3);
    }

    #[test]
    fn class_bound_drops_excess_buffers() {
        let arena = PackArena::new();
        let bufs: Vec<Vec<u8>> =
            (0..MAX_FREE_PER_CLASS + 4).map(|_| arena.checkout::<u8>(128)).collect();
        for b in bufs {
            arena.recycle(b);
        }
        assert_eq!(arena.stats().returned, MAX_FREE_PER_CLASS as u64);
    }

    #[test]
    fn zero_length_checkout_is_served() {
        let arena = PackArena::new();
        let v: Vec<u8> = arena.checkout(0);
        assert!(v.is_empty());
        arena.recycle(v);
    }

    #[test]
    fn arena_is_shareable_across_threads() {
        use std::sync::Arc;
        let arena = Arc::new(PackArena::new());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let a = Arc::clone(&arena);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        let v: Vec<u8> = a.checkout(512);
                        a.recycle(v);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(arena.stats().checkouts, 400);
        // After the first few cold checkouts the free lists serve
        // everything: fresh is bounded by the thread count.
        assert!(arena.stats().fresh <= 4, "fresh = {}", arena.stats().fresh);
    }
}
