//! Symmetric signed quantisation — the i8 and i16 paths of the
//! mixed-precision suite.
//!
//! Symmetric quantisation maps `real ≈ scale · q` with `q` a signed
//! integer and **no zero point**, so the integer GEMM needs *no*
//! correction term at all: `A·B = sa·sb · (QA·QB)`. That is why
//! production int8 stacks quantise weights symmetrically — and why the
//! i8/i16 layers here are a straight [`crate::gemm::ParallelGemm::run_p`]
//! plus one scalar multiply, with the zero-point machinery of
//! [`super::qgemm`] reserved for the asymmetric u8 path.

use crate::gemm::precision::Element;
use crate::gemm::types::Mat;
use crate::gemm::Accum;

/// A signed integer element usable for symmetric quantisation.
pub trait IntElement: Element {
    /// Largest representable magnitude (symmetric range: ±QMAX).
    const QMAX: i32;
    fn from_i32_clamped(v: i32) -> Self;
}

impl IntElement for i8 {
    const QMAX: i32 = 127;
    fn from_i32_clamped(v: i32) -> i8 {
        v.clamp(-Self::QMAX, Self::QMAX) as i8
    }
}

impl IntElement for i16 {
    const QMAX: i32 = 32767;
    fn from_i32_clamped(v: i32) -> i16 {
        v.clamp(-Self::QMAX, Self::QMAX) as i16
    }
}

/// Symmetric quantisation parameters: `real ≈ scale · q`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SymQParams {
    /// Step size (symmetric: no zero point).
    pub scale: f32,
}

impl SymQParams {
    /// Fit the scale so ±`max_abs` covers the full ±`qmax` range.
    pub fn fit(max_abs: f32, qmax: i32) -> SymQParams {
        assert!(max_abs.is_finite() && max_abs >= 0.0, "bad range {max_abs}");
        let scale = if max_abs > 0.0 { max_abs / qmax as f32 } else { 1.0 };
        SymQParams { scale }
    }
}

/// A symmetric-quantised tensor at i8 or i16 storage.
#[derive(Debug, Clone, PartialEq)]
pub struct SymQTensor<T: IntElement> {
    /// The quantised codes.
    pub data: Mat<T>,
    /// The symmetric scale shared by every element.
    pub params: SymQParams,
}

impl<T: IntElement> SymQTensor<T> {
    /// Quantise a row-major f32 matrix with scale fit over its elements.
    pub fn from_f32(rows: usize, cols: usize, x: &[f32]) -> SymQTensor<T> {
        assert_eq!(x.len(), rows * cols, "data length mismatch");
        let max_abs = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let params = SymQParams::fit(if max_abs.is_finite() { max_abs } else { 0.0 }, T::QMAX);
        let data = Mat::from_vec(
            rows,
            cols,
            x.iter()
                .map(|&v| T::from_i32_clamped((v / params.scale).round() as i32))
                .collect(),
        );
        SymQTensor { data, params }
    }

    /// Dequantise back to f32 (row-major).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.data.iter().map(|&q| q.widen().to_f64() as f32 * self.params.scale).collect()
    }

    /// Max absolute quantisation error vs the original values.
    pub fn max_error(&self, x: &[f32]) -> f32 {
        self.to_f32().iter().zip(x).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }
}

/// Dequantise a symmetric integer GEMM accumulator: `sa·sb·qc`,
/// row-major f32. Works for both the i32 (i8 GEMM) and i64 (i16 GEMM)
/// accumulators.
pub fn sym_dequantize<A: Accum>(qc: &Mat<A>, sa: f32, sb: f32) -> Vec<f32> {
    let s = (sa as f64) * (sb as f64);
    qc.data.iter().map(|&v| (v.to_f64() * s) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::baseline::naive_gemm_p;
    use crate::util::quickcheck::prop;
    use crate::util::Pcg32;

    fn random_f32(n: usize, half_range: f32, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| (rng.f64() as f32 * 2.0 - 1.0) * half_range).collect()
    }

    #[test]
    fn i8_roundtrip_error_bounded_by_half_scale() {
        let mut rng = Pcg32::new(0x51);
        let x = random_f32(64, 4.0, &mut rng);
        let q = SymQTensor::<i8>::from_f32(8, 8, &x);
        assert!(q.max_error(&x) <= q.params.scale * 0.5 + 1e-6);
    }

    #[test]
    fn i16_is_much_finer_than_i8() {
        let mut rng = Pcg32::new(0x52);
        let x = random_f32(256, 2.0, &mut rng);
        let q8 = SymQTensor::<i8>::from_f32(16, 16, &x);
        let q16 = SymQTensor::<i16>::from_f32(16, 16, &x);
        assert!(q16.params.scale < q8.params.scale / 100.0);
        assert!(q16.max_error(&x) < q8.max_error(&x).max(1e-9));
    }

    #[test]
    fn zero_is_exact_and_sign_symmetric() {
        let x = [-1.0f32, 0.0, 1.0, -0.5];
        let q = SymQTensor::<i8>::from_f32(2, 2, &x);
        let back = q.to_f32();
        assert_eq!(back[1], 0.0, "zero must be exactly representable");
        assert_eq!(back[0], -back[2], "symmetric range");
    }

    #[test]
    fn degenerate_all_zero_tensor() {
        let x = [0.0f32; 4];
        let q = SymQTensor::<i16>::from_f32(2, 2, &x);
        assert_eq!(q.params.scale, 1.0);
        assert!(q.to_f32().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn symmetric_gemm_needs_no_correction() {
        // Quantise, run the integer GEMM, rescale — and land within the
        // accumulated quantisation error of the f32 product, with no
        // zero-point correction anywhere.
        let (m, k, n) = (8, 32, 6);
        let mut rng = Pcg32::new(0x53);
        let a = random_f32(m * k, 1.0, &mut rng);
        let b = random_f32(k * n, 0.5, &mut rng);
        let qa = SymQTensor::<i8>::from_f32(m, k, &a);
        let qb = SymQTensor::<i8>::from_f32(k, n, &b);
        let mut qc = Mat::<i32>::zeros(m, n);
        naive_gemm_p::<i8>(&qa.data, &qb.data, &mut qc);
        let y = sym_dequantize(&qc, qa.params.scale, qb.params.scale);
        let mut want = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    want[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        let bound = k as f32
            * (qa.params.scale * 0.5 * 0.5 + qb.params.scale * 0.5 * 1.0)
            + 1e-3;
        for (got, w) in y.iter().zip(&want) {
            assert!((got - w).abs() <= bound, "{got} vs {w} (bound {bound})");
        }
    }

    #[test]
    fn prop_sym_quantize_bounded_and_monotone() {
        prop("sym-quant-bounded", 0x54, 40, |g| {
            let n = g.dim(32);
            let x = random_f32(n * n, 1.0 + g.rng.f64() as f32 * 8.0, &mut g.rng);
            let q = SymQTensor::<i16>::from_f32(n, n, &x);
            let err = q.max_error(&x);
            if err > q.params.scale * 0.5 + 1e-4 {
                return Err(format!("error {err} > half-scale {}", q.params.scale));
            }
            Ok(())
        });
    }
}
