//! Affine quantisation parameters and quantised tensors.

use crate::gemm::MatU8;

/// Per-tensor affine quantisation: `real ≈ scale · (q − zero_point)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    /// Step size between adjacent quantised values.
    pub scale: f32,
    /// The u8 code representing real 0.0.
    pub zero_point: i32,
}

impl QParams {
    /// Choose parameters covering `[lo, hi]` with the full u8 range,
    /// following the standard asymmetric-quantisation recipe (zero is
    /// exactly representable, as required for zero-padded packing to be
    /// value-neutral after correction).
    pub fn fit(lo: f32, hi: f32) -> QParams {
        assert!(lo.is_finite() && hi.is_finite() && lo <= hi, "bad range [{lo}, {hi}]");
        // Always include 0 in the range so zero_point ∈ [0, 255].
        let lo = lo.min(0.0);
        let hi = hi.max(0.0);
        let scale = if hi > lo { (hi - lo) / 255.0 } else { 1.0 };
        let zero_point = (-lo / scale).round().clamp(0.0, 255.0) as i32;
        QParams { scale, zero_point }
    }

    /// Real → u8 code (round, clamp to \[0, 255\]).
    #[inline]
    pub fn quantize(&self, x: f32) -> u8 {
        ((x / self.scale).round() as i32 + self.zero_point).clamp(0, 255) as u8
    }

    /// u8 code → real.
    #[inline]
    pub fn dequantize(&self, q: u8) -> f32 {
        self.scale * (q as i32 - self.zero_point) as f32
    }
}

/// A u8 tensor together with its quantisation parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    /// The quantised codes.
    pub data: MatU8,
    /// The affine parameters shared by every element.
    pub params: QParams,
}

impl QTensor {
    /// Quantise a row-major f32 matrix with range fit over its elements.
    pub fn from_f32(rows: usize, cols: usize, x: &[f32]) -> QTensor {
        assert_eq!(x.len(), rows * cols);
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in x {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if !lo.is_finite() {
            lo = 0.0;
            hi = 0.0;
        }
        let params = QParams::fit(lo, hi);
        let data = MatU8::from_vec(rows, cols, x.iter().map(|&v| params.quantize(v)).collect());
        QTensor { data, params }
    }

    /// Dequantise back to f32 (row-major).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.data.iter().map(|&q| self.params.dequantize(q)).collect()
    }

    /// Max absolute quantisation error vs the original values.
    pub fn max_error(&self, x: &[f32]) -> f32 {
        self.to_f32()
            .iter()
            .zip(x)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::prop;

    #[test]
    fn zero_is_exact() {
        for (lo, hi) in [(-1.0f32, 1.0), (0.0, 6.0), (-3.0, 0.5)] {
            let p = QParams::fit(lo, hi);
            assert_eq!(p.dequantize(p.quantize(0.0)), 0.0, "range [{lo},{hi}]");
        }
    }

    #[test]
    fn roundtrip_error_bounded_by_half_scale() {
        let p = QParams::fit(-4.0, 4.0);
        for i in 0..=800 {
            let x = -4.0 + i as f32 * 0.01;
            let err = (p.dequantize(p.quantize(x)) - x).abs();
            assert!(err <= p.scale * 0.5 + 1e-6, "x={x} err={err}");
        }
    }

    #[test]
    fn clamps_out_of_range() {
        let p = QParams::fit(0.0, 1.0);
        assert_eq!(p.quantize(99.0), 255);
        assert_eq!(p.quantize(-99.0), 0);
    }

    #[test]
    fn degenerate_range_handled() {
        let p = QParams::fit(0.0, 0.0);
        assert_eq!(p.quantize(0.0), 0);
        assert_eq!(p.dequantize(p.quantize(0.0)), 0.0);
    }

    #[test]
    fn qtensor_roundtrip_small() {
        let x = vec![-1.0f32, -0.5, 0.0, 0.5, 1.0, 2.0];
        let t = QTensor::from_f32(2, 3, &x);
        assert!(t.max_error(&x) <= t.params.scale * 0.5 + 1e-6);
    }

    #[test]
    fn prop_quantize_monotone_and_bounded() {
        prop("quant-monotone", 0x0A7, 60, |g| {
            let lo = -(g.rng.f64() as f32) * 10.0;
            let hi = g.rng.f64() as f32 * 10.0;
            let p = QParams::fit(lo, hi);
            let mut prev_q = 0u8;
            for i in 0..=100 {
                let x = lo + (hi - lo) * i as f32 / 100.0;
                let q = p.quantize(x);
                if i > 0 && q < prev_q {
                    return Err(format!("non-monotone at x={x}"));
                }
                prev_q = q;
                let err = (p.dequantize(q) - x).abs();
                if err > p.scale * 0.5 + 1e-4 {
                    return Err(format!("error {err} > half-scale at x={x}"));
                }
            }
            Ok(())
        });
    }
}
