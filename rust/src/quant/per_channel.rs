//! Per-channel (per-output-column) weight quantisation — the "adaptive
//! precision" refinement the paper's motivation points at (§1: demand
//! for adaptive-precision inference).
//!
//! Per-tensor quantisation spends one scale on the whole weight matrix;
//! when column magnitudes differ by orders of magnitude the small columns
//! lose all resolution. Per-channel keeps one (scale, zero-point) per
//! output column at identical integer-GEMM cost (the correction and the
//! dequantisation are already per-column operations).

use super::qparams::QParams;
use crate::gemm::{MatI32, MatU8};

/// A u8 weight matrix quantised with per-output-column parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct PerChannelWeights {
    /// The quantised codes.
    pub data: MatU8,
    /// Affine parameters, one per output column.
    pub params: Vec<QParams>, // one per column
}

impl PerChannelWeights {
    /// Quantise an `in_dim × out_dim` f32 weight matrix column-wise.
    pub fn from_f32(in_dim: usize, out_dim: usize, w: &[f32]) -> PerChannelWeights {
        assert_eq!(w.len(), in_dim * out_dim);
        let mut params = Vec::with_capacity(out_dim);
        let mut data = MatU8::zeros(in_dim, out_dim);
        for j in 0..out_dim {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for i in 0..in_dim {
                let v = w[i * out_dim + j];
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if !lo.is_finite() {
                lo = 0.0;
                hi = 0.0;
            }
            let p = QParams::fit(lo, hi);
            for i in 0..in_dim {
                data.set(i, j, p.quantize(w[i * out_dim + j]));
            }
            params.push(p);
        }
        PerChannelWeights { data, params }
    }

    /// Dequantise back to f32 (row-major) for error analysis.
    pub fn to_f32(&self) -> Vec<f32> {
        let (rows, cols) = (self.data.rows, self.data.cols);
        let mut out = vec![0.0f32; rows * cols];
        for i in 0..rows {
            for j in 0..cols {
                out[i * cols + j] = self.params[j].dequantize(self.data.at(i, j));
            }
        }
        out
    }

    /// Max |error| vs the original weights.
    pub fn max_error(&self, w: &[f32]) -> f32 {
        self.to_f32().iter().zip(w).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }
}

/// `y = x · W` with per-channel weights: integer GEMM + per-column
/// correction and dequantisation. `xq`/`xp` quantise the activations
/// per-tensor (dynamic), exactly like the per-tensor path.
pub fn per_channel_matmul(
    xq: &MatU8,
    xp: QParams,
    w: &PerChannelWeights,
    gemm: impl FnOnce(&MatU8, &MatU8, &mut MatI32),
) -> Vec<f32> {
    let (m, k) = (xq.rows, xq.cols);
    let n = w.data.cols;
    assert_eq!(k, w.data.rows, "inner dims");
    let mut qc = MatI32::zeros(m, n);
    gemm(xq, &w.data, &mut qc);

    let row_sums: Vec<i32> = (0..m)
        .map(|i| (0..k).map(|p| xq.at(i, p) as i32).sum())
        .collect();
    let col_sums: Vec<i32> = (0..n)
        .map(|j| (0..k).map(|p| w.data.at(p, j) as i32).sum())
        .collect();

    let mut y = vec![0.0f32; m * n];
    for j in 0..n {
        let wp = w.params[j];
        let s = xp.scale * wp.scale;
        for i in 0..m {
            let corrected = qc.at(i, j) - xp.zero_point * col_sums[j]
                - wp.zero_point * row_sums[i]
                + k as i32 * xp.zero_point * wp.zero_point;
            y[i * n + j] = s * corrected as f32;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::baseline::naive_gemm;
    use crate::quant::QTensor;
    use crate::util::quickcheck::prop;
    use crate::util::Pcg32;

    fn f32_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for p in 0..k {
                for j in 0..n {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    /// Weights with wildly different column scales — per-channel's case.
    fn skewed_weights(k: usize, n: usize, rng: &mut Pcg32) -> Vec<f32> {
        let mut w = vec![0.0f32; k * n];
        for j in 0..n {
            let col_scale = 10.0f32.powi(j as i32 % 4); // 1, 10, 100, 1000
            for i in 0..k {
                w[i * n + j] = (rng.f64() as f32 * 2.0 - 1.0) * col_scale;
            }
        }
        w
    }

    #[test]
    fn per_channel_beats_per_tensor_on_skewed_columns() {
        let mut rng = Pcg32::new(80);
        let (k, n) = (64, 8);
        let w = skewed_weights(k, n, &mut rng);
        let pc = PerChannelWeights::from_f32(k, n, &w);
        let pt = QTensor::from_f32(k, n, &w);
        // Compare error on the SMALL columns (col_scale = 1).
        let pc_err: f32 = (0..k)
            .map(|i| (pc.to_f32()[i * n] - w[i * n]).abs())
            .fold(0.0, f32::max);
        let pt_deq = pt.to_f32();
        let pt_err: f32 = (0..k).map(|i| (pt_deq[i * n] - w[i * n]).abs()).fold(0.0, f32::max);
        assert!(
            pc_err * 10.0 < pt_err,
            "per-channel {pc_err} should be ≫ better than per-tensor {pt_err}"
        );
    }

    #[test]
    fn matmul_matches_f32_within_column_bounds() {
        let mut rng = Pcg32::new(81);
        let (m, k, n) = (4, 48, 6);
        let x: Vec<f32> = (0..m * k).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
        let w = skewed_weights(k, n, &mut rng);
        let qx = QTensor::from_f32(m, k, &x);
        let pcw = PerChannelWeights::from_f32(k, n, &w);
        let got = per_channel_matmul(&qx.data, qx.params, &pcw, naive_gemm);
        let want = f32_gemm(m, k, n, &x, &w);
        for j in 0..n {
            let bound = k as f32
                * (qx.params.scale * 0.5 * 10f32.powi(j as i32 % 4)
                    + pcw.params[j].scale * 0.5 * 1.0
                    + qx.params.scale * pcw.params[j].scale * 0.25)
                + 1e-3;
            for i in 0..m {
                let e = (got[i * n + j] - want[i * n + j]).abs();
                assert!(e <= bound, "({i},{j}): err {e} > bound {bound}");
            }
        }
    }

    #[test]
    fn per_channel_roundtrip_error_bounded() {
        let mut rng = Pcg32::new(82);
        let w: Vec<f32> = (0..32 * 4).map(|_| rng.f64() as f32 * 4.0 - 2.0).collect();
        let pc = PerChannelWeights::from_f32(32, 4, &w);
        let max_scale = pc.params.iter().map(|p| p.scale).fold(0.0, f32::max);
        assert!(pc.max_error(&w) <= max_scale * 0.5 + 1e-6);
    }

    #[test]
    fn prop_per_channel_never_worse_than_per_tensor() {
        prop("pc-vs-pt", 0x9C, 30, |g| {
            let k = g.dim(32).max(2);
            let n = g.dim(8).max(1);
            let w: Vec<f32> = (0..k * n)
                .map(|_| (g.rng.f64() as f32 * 2.0 - 1.0) * 10f32.powi(g.rng.below(3) as i32))
                .collect();
            let pc = PerChannelWeights::from_f32(k, n, &w);
            let pt = QTensor::from_f32(k, n, &w);
            // Per-channel's worst error must satisfy the per-tensor
            // guarantee (≤ global scale/2): each column scale ≤ the
            // global scale, so the per-channel bound is never looser.
            // (Realised errors can cross by rounding luck inside the
            // half-scale band, so we compare bounds, not samples.)
            let pcd = pc.to_f32();
            let e_pc = pcd.iter().zip(&w).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
            if e_pc > pt.params.scale * 0.5 + 1e-5 {
                return Err(format!(
                    "per-channel err {e_pc} exceeds per-tensor bound {}",
                    pt.params.scale * 0.5
                ));
            }
            for (j, p) in pc.params.iter().enumerate() {
                if p.scale > pt.params.scale + 1e-7 {
                    return Err(format!("column {j} scale {} > global {}", p.scale, pt.params.scale));
                }
            }
            Ok(())
        });
    }
}
