//! Mixed-precision support: quantisation for the u8/i8/i16 integer paths.
//!
//! The paper motivates its micro-kernels by "the strong demand for
//! adaptive-precision inference in deep learning" (§1, §4.2). This module
//! supplies the numerical machinery that makes the integer GEMMs usable
//! as *neural-network layers*:
//!
//! - [`qparams`]/[`qgemm`] — per-tensor *affine* quantisation for the u8
//!   kernel (`q = round(x/scale) + zero_point`) and the zero-point
//!   correction that turns the unsigned GEMM back into a real product.
//! - [`sym`] — *symmetric* signed quantisation for the i8 and i16
//!   kernels (`real ≈ scale · q`, no zero point, no correction term).
//! - the bf16 path needs no quantisation at all: operands are
//!   bf16-rounded casts (see [`crate::gemm::Bf16`]).

mod per_channel;
mod qgemm;
mod qparams;
mod sym;

pub use per_channel::{per_channel_matmul, PerChannelWeights};
pub use qgemm::{dequantize_gemm_i32, quantized_linear, zero_point_correction};
pub use qparams::{QParams, QTensor};
pub use sym::{sym_dequantize, IntElement, SymQParams, SymQTensor};
