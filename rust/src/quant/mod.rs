//! Mixed-precision support: affine quantisation for UINT8 inference.
//!
//! The paper motivates its UINT8 micro-kernel by "the strong demand for
//! adaptive-precision inference in deep learning" (§1, §4.2). This module
//! supplies the numerical machinery that makes a u8·u8→i32 GEMM usable as
//! a *neural-network layer*: per-tensor affine quantisation
//! (`q = round(x/scale) + zero_point`), the zero-point correction that
//! turns an integer GEMM over quantised operands back into a real-valued
//! product, and requantisation of i32 accumulators to u8 activations.

mod per_channel;
mod qgemm;
mod qparams;

pub use per_channel::{per_channel_matmul, PerChannelWeights};
pub use qgemm::{dequantize_gemm_i32, quantized_linear, zero_point_correction};
pub use qparams::{QParams, QTensor};
