//! Quantised GEMM: turning the integer kernel into a real-valued layer.
//!
//! For affine-quantised operands `a = sa·(qa − za)`, `b = sb·(qb − zb)`,
//! the real product is
//!
//! ```text
//! A·B = sa·sb · [ QA·QB − za·colsum(QB) − zb·rowsum(QA) + k·za·zb ]
//! ```
//!
//! `QA·QB` is exactly the u8 GEMM the paper's micro-kernel computes; the
//! three correction terms are O(m·n) and O(m·k + k·n) — negligible next
//! to the O(m·n·k) product, which is why production int8 inference stacks
//! (and this one) run them on the host/ARM core rather than the AIEs.

use super::qparams::QParams;
use crate::gemm::{MatI32, MatU8};

/// The zero-point correction term for `C[i][j]`:
/// `− za·colsum_j(QB) − zb·rowsum_i(QA) + k·za·zb`.
pub fn zero_point_correction(
    qa: &MatU8,
    qb: &MatU8,
    pa: QParams,
    pb: QParams,
) -> MatI32 {
    assert_eq!(qa.cols, qb.rows);
    let k = qa.cols as i32;
    let row_sums: Vec<i32> = (0..qa.rows)
        .map(|i| (0..qa.cols).map(|p| qa.at(i, p) as i32).sum())
        .collect();
    let col_sums: Vec<i32> = (0..qb.cols)
        .map(|j| (0..qb.rows).map(|p| qb.at(p, j) as i32).sum())
        .collect();
    let mut corr = MatI32::zeros(qa.rows, qb.cols);
    for i in 0..qa.rows {
        for j in 0..qb.cols {
            let c = -pa.zero_point * col_sums[j] - pb.zero_point * row_sums[i]
                + k * pa.zero_point * pb.zero_point;
            corr.add(i, j, c);
        }
    }
    corr
}

/// Dequantise an integer GEMM result (`qc = QA·QB` plus correction) into
/// real values: `sa·sb·qc`.
pub fn dequantize_gemm_i32(qc: &MatI32, pa: QParams, pb: QParams) -> Vec<f32> {
    let s = pa.scale * pb.scale;
    qc.data.iter().map(|&v| v as f32 * s).collect()
}

/// Full quantised linear layer on top of an integer-GEMM closure:
/// `Y = dequant(QA·QB + correction) + bias`, returning row-major f32.
///
/// The closure runs the actual u8 GEMM (blocked, parallel, or the PJRT
/// artifact) so this module stays agnostic about *where* the MACs happen.
pub fn quantized_linear(
    qa: &MatU8,
    qb: &MatU8,
    pa: QParams,
    pb: QParams,
    bias: Option<&[f32]>,
    gemm: impl FnOnce(&MatU8, &MatU8, &mut MatI32),
) -> Vec<f32> {
    let mut qc = MatI32::zeros(qa.rows, qb.cols);
    gemm(qa, qb, &mut qc);
    let corr = zero_point_correction(qa, qb, pa, pb);
    for (c, &d) in qc.data.iter_mut().zip(&corr.data) {
        *c += d;
    }
    let mut y = dequantize_gemm_i32(&qc, pa, pb);
    if let Some(bias) = bias {
        assert_eq!(bias.len(), qb.cols);
        for i in 0..qa.rows {
            for j in 0..qb.cols {
                y[i * qb.cols + j] += bias[j];
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::baseline::naive_gemm;
    use crate::quant::QTensor;
    use crate::util::quickcheck::prop;
    use crate::util::Pcg32;

    /// f32 reference product.
    fn f32_gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                for p in 0..k {
                    c[i * n + j] += a[i * k + p] * b[p * n + j];
                }
            }
        }
        c
    }

    fn random_f32(n: usize, lo: f32, hi: f32, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| lo + (hi - lo) * rng.f64() as f32).collect()
    }

    #[test]
    fn quantized_gemm_tracks_f32_reference() {
        let (m, k, n) = (16, 32, 12);
        let mut rng = Pcg32::new(40);
        let a = random_f32(m * k, -1.0, 1.0, &mut rng);
        let b = random_f32(k * n, -0.5, 0.5, &mut rng);
        let qa = QTensor::from_f32(m, k, &a);
        let qb = QTensor::from_f32(k, n, &b);
        let y = quantized_linear(&qa.data, &qb.data, qa.params, qb.params, None, |a, b, c| {
            naive_gemm(a, b, c)
        });
        let want = f32_gemm(m, k, n, &a, &b);
        // Error bound: k · (sa/2·|b|max + sb/2·|a|max + sa·sb/4) per entry.
        let bound = k as f32
            * (qa.params.scale * 0.5 * 0.5
                + qb.params.scale * 0.5 * 1.0
                + qa.params.scale * qb.params.scale * 0.25)
            + 1e-3;
        for (i, (&got, &w)) in y.iter().zip(&want).enumerate() {
            assert!((got - w).abs() <= bound, "entry {i}: {got} vs {w} (bound {bound})");
        }
    }

    #[test]
    fn bias_is_added_per_column() {
        let qa = QTensor::from_f32(1, 1, &[1.0]);
        let qb = QTensor::from_f32(1, 2, &[1.0, 1.0]);
        let bias = [10.0f32, -10.0];
        let y = quantized_linear(&qa.data, &qb.data, qa.params, qb.params, Some(&bias), |a, b, c| {
            naive_gemm(a, b, c)
        });
        assert!((y[0] - 11.0).abs() < 0.1, "{y:?}");
        assert!((y[1] + 9.0).abs() < 0.1, "{y:?}");
    }

    #[test]
    fn correction_zero_when_zero_points_zero() {
        // Non-negative data ⇒ zero_point = 0 ⇒ correction must vanish.
        let mut rng = Pcg32::new(41);
        let a = random_f32(4 * 8, 0.0, 1.0, &mut rng);
        let b = random_f32(8 * 4, 0.0, 1.0, &mut rng);
        let qa = QTensor::from_f32(4, 8, &a);
        let qb = QTensor::from_f32(8, 4, &b);
        assert_eq!(qa.params.zero_point, 0);
        assert_eq!(qb.params.zero_point, 0);
        let corr = zero_point_correction(&qa.data, &qb.data, qa.params, qb.params);
        assert!(corr.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn prop_quantized_linear_error_scales_with_k() {
        prop("qgemm-error-bound", 0x0E55, 25, |g| {
            let m = g.dim(12);
            let k = g.dim(24);
            let n = g.dim(12);
            let a = random_f32(m * k, -2.0, 2.0, &mut g.rng);
            let b = random_f32(k * n, -2.0, 2.0, &mut g.rng);
            let qa = QTensor::from_f32(m, k, &a);
            let qb = QTensor::from_f32(k, n, &b);
            let y =
                quantized_linear(&qa.data, &qb.data, qa.params, qb.params, None, |a, b, c| {
                    naive_gemm(a, b, c)
                });
            let want = f32_gemm(m, k, n, &a, &b);
            let bound = k as f32
                * (qa.params.scale * 2.0 + qb.params.scale * 2.0
                    + qa.params.scale * qb.params.scale)
                + 1e-3;
            for (got, w) in y.iter().zip(&want) {
                if (got - w).abs() > bound {
                    return Err(format!("error {} > bound {bound} (k={k})", (got - w).abs()));
                }
            }
            Ok(())
        });
    }
}
