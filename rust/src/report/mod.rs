//! Reporting: paper-shaped table emitters shared by the CLI and benches.

use crate::arch::VersalArch;
use crate::gemm::parallel::{ParallelGemm, Table2Row};
use crate::sim::{AieTileModel, KernelMode};
use crate::util::tabulate::{Align, Table};

/// Format a cycle count like the paper's Table 2 ("3694.1 · 10^3").
pub fn fmt_kcycles(cycles: u64) -> String {
    format!("{:.1}e3", cycles as f64 / 1e3)
}

/// Paper reference values for Table 2 (for side-by-side printing).
pub const PAPER_TABLE2: [(usize, u64, u64, f64, f64); 6] = [
    // (tiles, copy_cr, arith, total, perf/tile)
    (1, 40, 4110, 3694.1e3, 31.5),
    (2, 58, 4110, 1916.0e3, 31.4),
    (4, 63, 4110, 958.1e3, 31.3),
    (8, 84, 4110, 498.9e3, 31.2),
    (16, 157, 4110, 275.3e3, 30.7),
    (32, 282, 4110, 162.9e3, 29.8),
];

/// Paper reference values for Table 3: (label, measured, theoretical).
pub const PAPER_TABLE3: [(&str, u64, u64); 3] = [
    ("read ar only", 4106, 4864),
    ("execute mac16() only", 1042, 1024),
    ("baseline", 4110, 5888),
];

/// Build Table 2 (model vs paper) for the given tile counts.
pub fn table2(arch: &VersalArch, tile_counts: &[usize]) -> Table {
    let g = ParallelGemm::new(arch);
    let mut t = Table::new(&[
        "#AIE tiles",
        "Copy Cr",
        "Arithmetic",
        "Total",
        "Perf/tile (MACs/cyc)",
        "paper Total",
        "paper Perf",
        "Δtotal %",
    ]);
    for &n in tile_counts {
        let row: Table2Row = g.table2_row(n);
        let paper = PAPER_TABLE2.iter().find(|p| p.0 == n);
        let (pt, pp, delta) = match paper {
            Some(&(_, _, _, total, perf)) => (
                fmt_kcycles(total as u64),
                format!("{perf:.1}"),
                format!("{:+.1}", (row.total_cycles as f64 - total) / total * 100.0),
            ),
            None => ("-".into(), "-".into(), "-".into()),
        };
        t.row(&[
            n.to_string(),
            row.copy_cr_cycles.to_string(),
            row.arithmetic_cycles.to_string(),
            fmt_kcycles(row.total_cycles),
            format!("{:.1}", row.perf_per_tile),
            pt,
            pp,
            delta,
        ]);
    }
    t
}

/// Build Table 3 (model vs paper) for kc = 2048.
pub fn table3(arch: &VersalArch) -> Table {
    let m = AieTileModel::new(arch);
    let mut t = Table::new(&[
        "Experiment",
        "Measured (model)",
        "Theoretical",
        "paper measured",
        "paper theoretical",
    ])
    .align(0, Align::Left);
    let rows = [
        ("read ar only", KernelMode::ReadArOnly),
        ("execute mac16() only", KernelMode::MacOnly),
        ("baseline", KernelMode::Baseline),
    ];
    for (i, (label, mode)) in rows.iter().enumerate() {
        let measured = m.kernel_cycles(2048, *mode, false).total;
        let theory = m.kernel_cycles_theoretical(2048, *mode);
        let (_, pm, pt) = PAPER_TABLE3[i];
        t.row(&[
            label.to_string(),
            measured.to_string(),
            theory.to_string(),
            pm.to_string(),
            pt.to_string(),
        ]);
    }
    t
}

/// Save a table as CSV under `bench_results/<name>.csv` (directory
/// created on demand) so bench runs leave machine-readable artifacts
/// next to the printed output. Returns the written path.
pub fn save_csv(name: &str, table: &Table) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var_os("VERSAL_BENCH_RESULTS").unwrap_or_else(|| "bench_results".into()),
    );
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    std::fs::write(&path, table.to_csv())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vc1902;

    #[test]
    fn table2_has_row_per_tile_count() {
        let t = table2(&vc1902(), &[1, 2, 4, 8, 16, 32]);
        assert_eq!(t.n_rows(), 6);
        let txt = t.to_text();
        assert!(txt.contains("31.5") || txt.contains("31.6"), "{txt}");
    }

    #[test]
    fn table3_reproduces_measured_column_exactly() {
        let txt = table3(&vc1902()).to_text();
        for v in ["4106", "1042", "4110", "4864", "1024", "5888"] {
            assert!(txt.contains(v), "missing {v} in\n{txt}");
        }
    }

    #[test]
    fn kcycles_format() {
        assert_eq!(fmt_kcycles(3_694_100), "3694.1e3");
    }

    #[test]
    fn save_csv_writes_file() {
        let tmp = std::env::temp_dir().join("versal_csv_test");
        std::env::set_var("VERSAL_BENCH_RESULTS", &tmp);
        let path = save_csv("t2", &table2(&vc1902(), &[1, 32])).unwrap();
        std::env::remove_var("VERSAL_BENCH_RESULTS");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("#AIE tiles,"));
        assert_eq!(text.lines().count(), 3);
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
