//! Reporting: paper-shaped table emitters shared by the CLI and benches.

use crate::arch::{human_bytes, VersalArch};
use crate::cluster::{
    Cluster, ClusterError, ClusterGemm, ClusterGemmConfig, FabricSpec, Topology,
};
use crate::coordinator::{LatencyStats, ServingReport};
use crate::gemm::parallel::{ParallelGemm, Table2Row};
use crate::gemm::{tuner, GemmConfig, Precision, MR, NR};
use crate::plan::LevelFootprint;
use crate::sim::{AieTileModel, Gmio, KernelMode};
use crate::util::tabulate::{Align, Table};

/// Format a cycle count like the paper's Table 2 ("3694.1 · 10^3").
pub fn fmt_kcycles(cycles: u64) -> String {
    format!("{:.1}e3", cycles as f64 / 1e3)
}

/// Paper reference values for Table 2 (for side-by-side printing).
pub const PAPER_TABLE2: [(usize, u64, u64, f64, f64); 6] = [
    // (tiles, copy_cr, arith, total, perf/tile)
    (1, 40, 4110, 3694.1e3, 31.5),
    (2, 58, 4110, 1916.0e3, 31.4),
    (4, 63, 4110, 958.1e3, 31.3),
    (8, 84, 4110, 498.9e3, 31.2),
    (16, 157, 4110, 275.3e3, 30.7),
    (32, 282, 4110, 162.9e3, 29.8),
];

/// Paper reference values for Table 3: (label, measured, theoretical).
pub const PAPER_TABLE3: [(&str, u64, u64); 3] = [
    ("read ar only", 4106, 4864),
    ("execute mac16() only", 1042, 1024),
    ("baseline", 4110, 5888),
];

/// Build Table 2 (model vs paper) for the given tile counts.
pub fn table2(arch: &VersalArch, tile_counts: &[usize]) -> Table {
    let g = ParallelGemm::new(arch);
    let mut t = Table::new(&[
        "#AIE tiles",
        "Copy Cr",
        "Arithmetic",
        "Total",
        "Perf/tile (MACs/cyc)",
        "paper Total",
        "paper Perf",
        "Δtotal %",
    ]);
    for &n in tile_counts {
        let row: Table2Row = g.table2_row(n);
        let paper = PAPER_TABLE2.iter().find(|p| p.0 == n);
        let (pt, pp, delta) = match paper {
            Some(&(_, _, _, total, perf)) => (
                fmt_kcycles(total as u64),
                format!("{perf:.1}"),
                format!("{:+.1}", (row.total_cycles as f64 - total) / total * 100.0),
            ),
            None => ("-".into(), "-".into(), "-".into()),
        };
        t.row(&[
            n.to_string(),
            row.copy_cr_cycles.to_string(),
            row.arithmetic_cycles.to_string(),
            fmt_kcycles(row.total_cycles),
            format!("{:.1}", row.perf_per_tile),
            pt,
            pp,
            delta,
        ]);
    }
    t
}

/// Build Table 3 (model vs paper) for kc = 2048.
pub fn table3(arch: &VersalArch) -> Table {
    let m = AieTileModel::new(arch);
    let mut t = Table::new(&[
        "Experiment",
        "Measured (model)",
        "Theoretical",
        "paper measured",
        "paper theoretical",
    ])
    .align(0, Align::Left);
    let rows = [
        ("read ar only", KernelMode::ReadArOnly),
        ("execute mac16() only", KernelMode::MacOnly),
        ("baseline", KernelMode::Baseline),
    ];
    for (i, (label, mode)) in rows.iter().enumerate() {
        let measured = m.kernel_cycles(2048, *mode, false).total;
        let theory = m.kernel_cycles_theoretical(2048, *mode);
        let (_, pm, pt) = PAPER_TABLE3[i];
        t.row(&[
            label.to_string(),
            measured.to_string(),
            theory.to_string(),
            pm.to_string(),
            pt.to_string(),
        ]);
    }
    t
}

/// The paper's fixed Table-2 problem, reused by the cluster scaling
/// table: (m, n, k) = (256, 256, 2048) ⇒ 2^27 MACs.
pub const TABLE2_PROBLEM: (usize, usize, usize) = (256, 256, 2048);

/// One row of the device-level scaling table (Table 2, one level up).
#[derive(Debug, Clone)]
pub struct ClusterScalingRow {
    /// Devices in the pool.
    pub devices: usize,
    /// AIE tiles per device.
    pub tiles_per_device: usize,
    /// Placement grid (rows, cols).
    pub grid: (usize, usize),
    /// Critical-path compute cycles.
    pub compute_cycles: u64,
    /// Communication left exposed after prefetch overlap.
    pub exposed_comm_cycles: u64,
    /// Wall-clock cycles of the cluster schedule.
    pub total_cycles: u64,
    /// Aggregate MACs/cycle over the cluster wall clock.
    pub aggregate_macs_per_cycle: f64,
    /// Per-device throughput as a fraction of the 1-device figure.
    pub per_device_efficiency: f64,
}

/// Compute the Table-2-style strong-scaling rows for homogeneous ring
/// clusters of the given sizes on the paper's reference problem.
pub fn cluster_scaling_rows(
    arch: &VersalArch,
    tiles_per_device: usize,
    device_counts: &[usize],
    fabric: &FabricSpec,
) -> Result<Vec<ClusterScalingRow>, ClusterError> {
    let (m, n, k) = TABLE2_PROBLEM;
    let macs = (m * n * k) as u64;
    let cfg = ClusterGemmConfig::paper_table2();
    let row = |d: usize| -> Result<ClusterScalingRow, ClusterError> {
        let cluster = Cluster::homogeneous(
            d,
            arch.clone(),
            tiles_per_device,
            Topology::Ring(d),
            fabric.clone(),
        )?;
        let engine = ClusterGemm::new(&cluster);
        let (bd, placement) = engine.schedule_auto(&cfg, m, n, k)?;
        Ok(ClusterScalingRow {
            devices: d,
            tiles_per_device,
            grid: (placement.rows, placement.cols),
            compute_cycles: bd.compute,
            exposed_comm_cycles: bd.exposed_comm,
            total_cycles: bd.total,
            aggregate_macs_per_cycle: bd.macs_per_cycle(macs),
            per_device_efficiency: 0.0, // filled below
        })
    };
    let base_row = row(1)?;
    let base = base_row.aggregate_macs_per_cycle;
    let mut rows = Vec::with_capacity(device_counts.len());
    for &d in device_counts {
        let mut r = if d == 1 { base_row.clone() } else { row(d)? };
        r.per_device_efficiency = r.aggregate_macs_per_cycle / r.devices as f64 / base;
        rows.push(r);
    }
    Ok(rows)
}

/// One row of the mixed-precision comparison table: the Table-2 problem
/// evaluated at one precision of the §4.2 kernel family.
#[derive(Debug, Clone)]
pub struct PrecisionRow {
    /// The row’s precision.
    pub precision: Precision,
    /// Bytes per input element.
    pub elem_bytes: u64,
    /// MACs per AIE vector op (§2 datapath widths).
    pub macs_per_vec_op: u64,
    /// kc the precision's Br panel admits (≤ the paper's 2048).
    pub kc: usize,
    /// Isolated micro-kernel loop cycles at that kc (Table-3 condition).
    pub kernel_cycles: u64,
    /// Contended Cr round trip at the row's tile count.
    pub copy_cr_cycles: u64,
    /// Paper-style per-tile metric: kernel MACs / (kernel + Cr cycles).
    pub kernel_macs_per_cycle: f64,
    /// Full Table-2-problem schedule at the row's tile count.
    pub total_cycles: u64,
    /// Aggregate MACs/cycle over the whole problem.
    pub aggregate_macs_per_cycle: f64,
    /// Predicted relative error at the problem's k (the tuner's model).
    pub rel_error: f64,
}

/// Evaluate the Table-2 problem across the whole precision suite on
/// `tiles` tiles. Each precision runs under its own feasible
/// paper-shaped CCP ([`tuner::ccp_for_precision`]); the u8 row is the
/// paper's configuration exactly.
pub fn precision_rows(arch: &VersalArch, tiles: usize) -> Vec<PrecisionRow> {
    let (m, n, k) = TABLE2_PROBLEM;
    let macs = (m * n * k) as u64;
    let model = AieTileModel::new(arch);
    let gmio = Gmio::new(arch);
    Precision::ALL
        .iter()
        .map(|&prec| {
            let ccp = tuner::ccp_for_precision(arch, prec);
            let mut cfg = GemmConfig::paper_table2(tiles);
            cfg.ccp = ccp;
            let kernel =
                model.kernel_cycles_p(ccp.kc, KernelMode::Baseline, false, prec).total;
            let cr = gmio.cr_roundtrip_cycles_p(tiles, prec);
            let kernel_macs = (MR * NR * ccp.kc) as f64;
            let total = tuner::predict_cycles_p(arch, &cfg, m, n, k, prec);
            PrecisionRow {
                precision: prec,
                elem_bytes: prec.elem_bytes(),
                macs_per_vec_op: prec.macs_per_vec_op(),
                kc: ccp.kc,
                kernel_cycles: kernel,
                copy_cr_cycles: cr,
                kernel_macs_per_cycle: kernel_macs / (kernel + cr) as f64,
                total_cycles: total,
                aggregate_macs_per_cycle: macs as f64 / total as f64,
                rel_error: prec.quant_rel_error(k),
            }
        })
        .collect()
}

/// Render the precision rows as a printable table.
pub fn precision_table(rows: &[PrecisionRow]) -> Table {
    let mut t = Table::new(&[
        "precision",
        "B/elem",
        "MACs/op",
        "kc",
        "kernel cyc",
        "Copy Cr",
        "MACs/cyc/tile",
        "Total",
        "Aggregate MACs/cyc",
        "rel err @k",
    ])
    .align(0, Align::Left);
    for r in rows {
        t.row(&[
            r.precision.to_string(),
            r.elem_bytes.to_string(),
            r.macs_per_vec_op.to_string(),
            r.kc.to_string(),
            r.kernel_cycles.to_string(),
            r.copy_cr_cycles.to_string(),
            format!("{:.1}", r.kernel_macs_per_cycle),
            fmt_kcycles(r.total_cycles),
            format!("{:.1}", r.aggregate_macs_per_cycle),
            format!("{:.1e}", r.rel_error),
        ]);
    }
    t
}

/// Render the cluster scaling rows as a printable table.
pub fn cluster_table(rows: &[ClusterScalingRow]) -> Table {
    let mut t = Table::new(&[
        "#devices",
        "grid",
        "tiles/dev",
        "Compute",
        "Exposed comm",
        "Total",
        "Aggregate MACs/cyc",
        "Eff/dev %",
    ]);
    for r in rows {
        t.row(&[
            r.devices.to_string(),
            format!("{}x{}", r.grid.0, r.grid.1),
            r.tiles_per_device.to_string(),
            fmt_kcycles(r.compute_cycles),
            fmt_kcycles(r.exposed_comm_cycles),
            fmt_kcycles(r.total_cycles),
            format!("{:.1}", r.aggregate_macs_per_cycle),
            format!("{:.1}", r.per_device_efficiency * 100.0),
        ]);
    }
    t
}

/// Render a plan's per-level footprint/residency accounting as a
/// table: Table 1's rows (memory, cache analogue, operands) extended
/// with the plan's peak residency, the level's budget (capacity minus
/// any reserved slice) and the resulting utilisation — the §3/Table-1
/// "flexible exploitation of the memory hierarchy", as numbers for one
/// concrete plan. Takes the footprint rows themselves so both the
/// materialized [`crate::plan::GemmPlan::footprints`] and the streaming
/// [`crate::plan::PlanSpec::footprints`] render through one table.
pub fn footprint_table(footprints: &[LevelFootprint]) -> Table {
    let mut t = Table::new(&[
        "Memory",
        "Cache",
        "Operands",
        "Peak resident",
        "Budget",
        "Capacity",
        "Util %",
    ])
    .align(0, Align::Left)
    .align(1, Align::Left)
    .align(2, Align::Left);
    for fp in footprints {
        t.row(&[
            fp.level.name().to_string(),
            fp.level.cache_analogue().to_string(),
            fp.level.operands().to_string(),
            human_bytes(fp.peak_bytes),
            human_bytes(fp.budget_bytes()),
            human_bytes(fp.capacity_bytes),
            format!("{:.1}", fp.utilisation() * 100.0),
        ]);
    }
    t
}

/// Render a continuous-batching runtime report as a summary table:
/// request accounting, fused-batch shape, packed-cache behaviour, the
/// stage cycle split and the pipelined-vs-sequential makespans.
pub fn serving_table(r: &ServingReport) -> Table {
    let mut t = Table::new(&["metric", "value"]).align(0, Align::Left).align(1, Align::Left);
    let mut kv = |k: &str, v: String| {
        t.row(&[k.to_string(), v]);
    };
    kv("requests completed", r.completed.to_string());
    kv("requests expired (SLO)", r.expired.to_string());
    kv("requests shed (overload)", r.shed.to_string());
    kv("requests rejected", r.rejected.to_string());
    kv("requests failed (backend)", r.failed.to_string());
    kv("fused batches", r.batches.to_string());
    kv("mean rows/batch", format!("{:.2}", r.mean_batch));
    kv(
        "cache hits / misses",
        format!(
            "{} / {} ({:.0}% hit rate)",
            r.cache.hits,
            r.cache.misses,
            r.cache.hit_rate() * 100.0
        ),
    );
    kv(
        "cache evictions / uncacheable",
        format!("{} / {}", r.cache.evictions, r.cache.uncacheable),
    );
    kv(
        "cache residency",
        format!(
            "{:.2} / {:.2} MiB",
            r.cache.bytes as f64 / (1u64 << 20) as f64,
            r.cache.budget_bytes as f64 / (1u64 << 20) as f64
        ),
    );
    kv(
        "plan cache hits / misses",
        format!(
            "{} / {} ({:.0}% hit rate)",
            r.plan_cache.hits,
            r.plan_cache.misses,
            r.plan_cache.hit_rate() * 100.0
        ),
    );
    kv(
        "plans lowered (miss path)",
        format!(
            "{} ({:.2} ms host lowering)",
            r.plan_cache.lowered,
            r.plan_cache.lower_ns as f64 / 1e6
        ),
    );
    kv("pack cycles", fmt_kcycles(r.pack_cycles));
    kv("transfer cycles", fmt_kcycles(r.transfer_cycles));
    kv("compute cycles", fmt_kcycles(r.compute_cycles));
    kv("sequential makespan", fmt_kcycles(r.sequential_cycles));
    kv("pipelined makespan", fmt_kcycles(r.pipelined_cycles));
    if r.pipelined_cycles > 0 {
        kv(
            "pipeline overlap win",
            format!(
                "{:.1}%",
                (1.0 - r.pipelined_cycles as f64 / r.sequential_cycles as f64) * 100.0
            ),
        );
        kv("requests / Mcycle", format!("{:.1}", r.requests_per_mcycle()));
    }
    // Fault accounting renders only when an injector actually did
    // something, so fault-free reports stay byte-identical to the
    // pre-fault format.
    if let Some(f) = &r.faults {
        if f.activity() {
            kv("faults injected", f.injected.to_string());
            kv("transient batch failures", f.transient_failures.to_string());
            kv(
                "retries (exhausted)",
                format!("{} ({})", f.retries, f.retry_exhausted),
            );
            kv(
                "recoveries / MTTR",
                format!("{} / {}", f.recoveries, fmt_kcycles(f.mttr_cycles)),
            );
            if let Some(first) = f.first_fault_us {
                kv("first fault at µs", first.to_string());
            }
            kv(
                "surviving capacity",
                format!("{:.0}%", f.capacity_fraction * 100.0),
            );
            kv(
                "goodput after first fault",
                format!(
                    "{:.1}% of {} submitted",
                    f.goodput_after_fault() * 100.0,
                    f.submitted_after_fault
                ),
            );
        }
    }
    // The end-to-end latency decomposition (tracer-independent: the
    // runtime always records the three legs; per request they sum to
    // the latency exactly).
    let legs: [(&str, &Option<LatencyStats>); 3] = [
        ("queue wait µs (mean/p95)", &r.queue_wait),
        ("batch wait µs (mean/p95)", &r.batch_wait),
        ("execute µs (mean/p95)", &r.execute),
    ];
    for (label, leg) in legs {
        if let Some(s) = leg {
            kv(label, format!("{:.1} / {:.1}", s.mean_us, s.p95_us));
        }
    }
    t
}

/// Render the per-tenant fairness rows of a multi-tenant serving report:
/// one row per class with goodput, in-SLO fraction, shed fraction and
/// the latency percentiles the overload invariants are asserted against.
pub fn tenant_table(r: &ServingReport) -> Table {
    let mut t = Table::new(&[
        "tenant",
        "prio",
        "slo ms",
        "submitted",
        "completed",
        "in-SLO %",
        "shed %",
        "expired",
        "retries",
        "p50 µs",
        "p99 µs",
    ])
    .align(0, Align::Left);
    for tr in &r.tenants {
        let (p50, p99) = match &tr.latency {
            Some(l) => (format!("{:.0}", l.p50_us), format!("{:.0}", l.p99_us)),
            None => ("-".to_string(), "-".to_string()),
        };
        t.row(&[
            tr.name.clone(),
            tr.priority.to_string(),
            format!("{:.1}", tr.slo_us as f64 / 1_000.0),
            tr.submitted.to_string(),
            tr.completed.to_string(),
            format!("{:.1}", tr.goodput_rate() * 100.0),
            format!("{:.1}", tr.shed_rate() * 100.0),
            tr.expired.to_string(),
            tr.retries.to_string(),
            p50,
            p99,
        ]);
    }
    t
}

/// Render a unified metrics registry snapshot
/// ([`crate::coordinator::ServingReport::metrics`]) as a two-column
/// table — every counter, gauge and histogram in deterministic order.
pub fn metrics_table(m: &crate::obs::MetricsRegistry) -> Table {
    let mut t = Table::new(&["metric", "value"]).align(0, Align::Left).align(1, Align::Left);
    for (k, v) in m.rows() {
        t.row(&[k, v]);
    }
    t
}

/// Render a latency distribution (µs) as a one-row percentile table.
pub fn latency_table(l: &LatencyStats) -> Table {
    let mut t = Table::new(&["count", "mean µs", "p50 µs", "p95 µs", "p99 µs", "max µs"]);
    t.row(&[
        l.count.to_string(),
        format!("{:.0}", l.mean_us),
        format!("{:.0}", l.p50_us),
        format!("{:.0}", l.p95_us),
        format!("{:.0}", l.p99_us),
        format!("{:.0}", l.max_us),
    ]);
    t
}

/// Save a table as CSV under `bench_results/<name>.csv` (directory
/// created on demand) so bench runs leave machine-readable artifacts
/// next to the printed output. Returns the written path.
pub fn save_csv(name: &str, table: &Table) -> std::io::Result<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var_os("VERSAL_BENCH_RESULTS").unwrap_or_else(|| "bench_results".into()),
    );
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    std::fs::write(&path, table.to_csv())?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::vc1902;

    #[test]
    fn table2_has_row_per_tile_count() {
        let t = table2(&vc1902(), &[1, 2, 4, 8, 16, 32]);
        assert_eq!(t.n_rows(), 6);
        let txt = t.to_text();
        assert!(txt.contains("31.5") || txt.contains("31.6"), "{txt}");
    }

    #[test]
    fn table3_reproduces_measured_column_exactly() {
        let txt = table3(&vc1902()).to_text();
        for v in ["4106", "1042", "4110", "4864", "1024", "5888"] {
            assert!(txt.contains(v), "missing {v} in\n{txt}");
        }
    }

    #[test]
    fn kcycles_format() {
        assert_eq!(fmt_kcycles(3_694_100), "3694.1e3");
    }

    #[test]
    fn cluster_scaling_rows_meet_acceptance_shape() {
        // The bench's acceptance criteria, pinned as a tier-1 test:
        // aggregate MACs/cycle strictly increases 1 → 4 devices and the
        // per-device efficiency stays ≥ 70% of the 1-device figure.
        let rows = cluster_scaling_rows(
            &vc1902(),
            8,
            &[1, 2, 4],
            &FabricSpec::pcie_like(),
        )
        .unwrap();
        assert_eq!(rows.len(), 3);
        assert!((rows[0].per_device_efficiency - 1.0).abs() < 1e-9);
        for w in rows.windows(2) {
            assert!(
                w[1].aggregate_macs_per_cycle > w[0].aggregate_macs_per_cycle,
                "aggregate throughput must rise: {} → {}",
                w[0].aggregate_macs_per_cycle,
                w[1].aggregate_macs_per_cycle
            );
        }
        for r in &rows {
            assert!(
                r.per_device_efficiency >= 0.70,
                "devices={}: efficiency {:.2}",
                r.devices,
                r.per_device_efficiency
            );
        }
        let t = cluster_table(&rows);
        assert_eq!(t.n_rows(), 3);
    }

    #[test]
    fn precision_rows_cover_suite_and_order_by_throughput() {
        let rows = precision_rows(&vc1902(), 8);
        assert_eq!(rows.len(), 4);
        // u8 row is the paper's configuration: kc 2048, 128 MACs/op.
        assert_eq!(rows[0].precision, Precision::U8);
        assert_eq!(rows[0].kc, 2048);
        assert_eq!(rows[0].macs_per_vec_op, 128);
        // The cycle model's throughput ordering: u8 ≥ i16 ≥ bf16.
        let get = |p: Precision| {
            rows.iter().find(|r| r.precision == p).unwrap().aggregate_macs_per_cycle
        };
        assert!(get(Precision::U8) >= get(Precision::I16), "u8 < i16");
        assert!(get(Precision::I16) >= get(Precision::Bf16), "i16 < bf16");
        // And the accuracy ordering runs the other way.
        let err = |p: Precision| rows.iter().find(|r| r.precision == p).unwrap().rel_error;
        assert!(err(Precision::Bf16) < err(Precision::I16));
        assert!(err(Precision::I16) < err(Precision::U8));
        let table = precision_table(&rows);
        assert_eq!(table.n_rows(), 4);
        let txt = table.to_text();
        assert!(txt.contains("bf16") && txt.contains("i16"), "{txt}");
    }

    #[test]
    fn serving_and_latency_tables_render() {
        use crate::coordinator::{CacheStats, PlanCacheStats, TenantReport};
        let report = ServingReport {
            completed: 10,
            expired: 1,
            shed: 4,
            rejected: 2,
            failed: 0,
            batches: 3,
            mean_batch: 3.33,
            cache: CacheStats {
                hits: 6,
                misses: 3,
                evictions: 1,
                uncacheable: 0,
                bytes: 1 << 20,
                budget_bytes: 4 << 20,
            },
            plan_cache: PlanCacheStats {
                hits: 4,
                misses: 2,
                evictions: 0,
                uncacheable: 0,
                bytes: 2048,
                budget_bytes: 1 << 20,
                lowered: 2,
                lower_ns: 1_500_000,
            },
            pack_cycles: 1000,
            transfer_cycles: 2000,
            compute_cycles: 3000,
            pipelined_cycles: 4500,
            sequential_cycles: 6000,
            latency: None,
            queue_wait: Some(LatencyStats {
                count: 10,
                mean_us: 12.0,
                p50_us: 11.0,
                p95_us: 20.0,
                p99_us: 29.0,
                max_us: 30.0,
            }),
            batch_wait: None,
            execute: None,
            tenants: vec![
                TenantReport {
                    name: "gold".into(),
                    priority: 3,
                    slo_us: 20_000,
                    submitted: 8,
                    completed: 7,
                    completed_in_slo: 6,
                    shed: 1,
                    expired: 0,
                    rejected: 0,
                    failed: 0,
                    retries: 0,
                    latency: Some(LatencyStats {
                        count: 7,
                        mean_us: 100.0,
                        p50_us: 90.0,
                        p95_us: 180.0,
                        p99_us: 200.0,
                        max_us: 210.0,
                    }),
                    cache: CacheStats::default(),
                    plan_cache: PlanCacheStats::default(),
                },
                TenantReport {
                    name: "free".into(),
                    retries: 0,
                    priority: 1,
                    slo_us: 200_000,
                    submitted: 6,
                    completed: 3,
                    completed_in_slo: 3,
                    shed: 3,
                    expired: 1,
                    rejected: 2,
                    failed: 0,
                    latency: None,
                    cache: CacheStats::default(),
                    plan_cache: PlanCacheStats::default(),
                },
            ],
            faults: None,
        };
        let txt = serving_table(&report).to_text();
        assert!(txt.contains("requests completed"), "{txt}");
        assert!(txt.contains("requests shed (overload)"), "{txt}");
        // The per-tenant fairness rows render one line per class.
        let tt = tenant_table(&report).to_text();
        assert!(tt.contains("gold") && tt.contains("free"), "{tt}");
        assert!(tt.contains("75.0"), "gold in-SLO % = 6/8: {tt}");
        assert!(tt.contains("50.0"), "free shed % = 3/6: {tt}");
        assert!(tt.contains("-"), "no-latency tenant renders dashes: {tt}");
        assert!(txt.contains("queue wait µs"), "{txt}");
        assert!(txt.contains("12.0 / 20.0"), "leg percentiles rendered: {txt}");
        assert!(!txt.contains("batch wait µs"), "absent legs are skipped: {txt}");
        // The same report folds into the unified registry and renders.
        let mt = metrics_table(&report.metrics()).to_text();
        assert!(mt.contains("requests_completed"), "{mt}");
        assert!(mt.contains("cache_hit_rate"), "{mt}");
        assert!(mt.contains("queue_wait_us"), "{mt}");
        assert!(txt.contains("67% hit rate"), "{txt}");
        assert!(txt.contains("plan cache hits / misses"), "{txt}");
        assert!(txt.contains("4 / 2"), "plan cache counters rendered: {txt}");
        assert!(txt.contains("plans lowered"), "{txt}");
        assert!(txt.contains("1.50 ms"), "lowering time rendered: {txt}");
        assert!(txt.contains("pipelined makespan"), "{txt}");
        assert!(txt.contains("25.0%"), "overlap win rendered: {txt}");
        let l = LatencyStats {
            count: 10,
            mean_us: 10.0,
            p50_us: 9.0,
            p95_us: 19.0,
            p99_us: 29.0,
            max_us: 30.0,
        };
        let lt = latency_table(&l).to_text();
        assert!(lt.contains("p99"), "{lt}");
        assert!(lt.contains("30"), "{lt}");
    }

    #[test]
    fn footprint_table_covers_all_levels() {
        use crate::plan::{GemmPlan, PlanSpec};
        let arch = vc1902();
        let plan = GemmPlan::lower(
            &arch,
            &GemmConfig::paper_table2(8),
            256,
            256,
            2048,
            Precision::U8,
            false,
        )
        .unwrap();
        let t = footprint_table(plan.footprints());
        assert_eq!(t.n_rows(), 5, "one row per memory level");
        let txt = t.to_text();
        // Table-1 residency of the paper problem: 512 KB Ac and Bc,
        // 16 KB Br, next to their level names.
        assert!(txt.contains("FPGA Ultra RAM"), "{txt}");
        assert!(txt.contains("512 KB"), "{txt}");
        assert!(txt.contains("16 KB"), "{txt}");
        assert!(txt.contains("Bc"), "{txt}");
        // The streaming spec's footprints render the identical table —
        // what `plan --cost-only` prints without materializing steps.
        let spec = PlanSpec::new(
            &arch,
            &GemmConfig::paper_table2(8),
            256,
            256,
            2048,
            Precision::U8,
            false,
        )
        .unwrap();
        assert_eq!(footprint_table(spec.footprints()).to_text(), txt);
    }

    #[test]
    fn save_csv_writes_file() {
        let tmp = std::env::temp_dir().join("versal_csv_test");
        std::env::set_var("VERSAL_BENCH_RESULTS", &tmp);
        let path = save_csv("t2", &table2(&vc1902(), &[1, 32])).unwrap();
        std::env::remove_var("VERSAL_BENCH_RESULTS");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("#AIE tiles,"));
        assert_eq!(text.lines().count(), 3);
        let _ = std::fs::remove_dir_all(&tmp);
    }
}
