//! The tuner's streaming-cost contract: predicting a schedule allocates
//! O(1) memory — **no per-candidate step vector** — however many blocks
//! the candidate's loop nest has.
//!
//! Pinned with a counting global allocator: the bytes allocated while
//! pricing a huge many-block problem must not exceed (a small slack
//! over) the bytes allocated while pricing a single-block one. The
//! pre-streaming path materialized ~88 B per step, so the big problem
//! below (32 768 compute blocks, ~100 k steps ≈ 8.6 MB of transient
//! steps) would fail the bound by three orders of magnitude.
//!
//! This file deliberately holds a single `#[test]`: the harness runs
//! tests of one binary concurrently, and a second test would race the
//! global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::{tuner, Ccp, GemmConfig, Precision};

struct CountingAlloc;

static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocated_during(f: impl FnOnce() -> u64) -> (u64, u64) {
    let before = BYTES.load(Ordering::SeqCst);
    let out = f();
    (out, BYTES.load(Ordering::SeqCst) - before)
}

#[test]
fn predict_cycles_allocates_o1_not_per_step() {
    let arch = vc1902();
    // Tiny-stride candidate on a small problem: 8 compute blocks.
    let mut small = GemmConfig::paper_table2(4);
    small.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    // The same tiny strides on a big problem: 32 × 32 × 32 = 32 768
    // compute blocks — ~100 k steps if anything materializes them.
    let big = small.clone();

    // Warm up once so lazily-initialised runtime state (thread locals,
    // stdio, ...) does not land in either measurement.
    let _ = tuner::predict_cycles_p(&arch, &small, 64, 64, 128, Precision::U8);

    let (small_cycles, small_bytes) = allocated_during(|| {
        tuner::predict_cycles_p(&arch, &small, 64, 64, 128, Precision::U8)
    });
    let (big_cycles, big_bytes) = allocated_during(|| {
        tuner::predict_cycles_p(&arch, &big, 1024, 1024, 2048, Precision::U8)
    });
    assert!(small_cycles > 0 && small_cycles != u64::MAX);
    assert!(big_cycles > small_cycles, "4096× the MACs must cost more");

    // O(1): the 4096×-bigger plan may not allocate step-proportional
    // memory. Allow generous constant slack (footprint rows, error
    // paths), but nothing near the ~8.6 MB a materialized step vector
    // would cost — or even one step vector of the small problem.
    assert!(
        big_bytes <= small_bytes + 4096,
        "streaming cost must be O(1) memory: big candidate allocated {big_bytes} B \
         vs small candidate's {small_bytes} B"
    );
}
