//! Chaos battery for the deterministic fault-injection subsystem.
//!
//! Every robustness invariant the degraded-mode serving path depends on
//! is pinned here, mostly as randomized properties over the mini
//! harness (`versal_gemm::util::quickcheck`):
//!
//! 1. **Seeded determinism** — identically-seeded fault storms replay
//!    to byte-identical report fingerprints *and* byte-identical Chrome
//!    traces (fault instants, degraded spans and retry events
//!    included);
//! 2. **Observational freeness** — a runtime with a zero-event
//!    [`FaultPlan`] attached is byte-identical (fingerprint and trace)
//!    to a runtime with no injector at all;
//! 3. **Conservation under storms** — per tenant and in aggregate,
//!    submitted = completed + failed + expired + shed + rejected, and a
//!    retry is the same request re-queued: it never re-counts a
//!    submission (the aggregate retry counter equals the per-tenant
//!    sum);
//! 4. **Deadline-aware retry** — a retry whose backoff lands at or past
//!    the request's deadline is never launched; with backoff ≥ SLO
//!    nothing ever completes, with a sane backoff service recovers;
//! 5. **Recovery accounting** — a transient batch fault opens a
//!    degraded window that closes on the next successful completion,
//!    with a non-zero MTTR in the cycle domain;
//! 6. **Goodput floor under device loss** — losing one of two devices
//!    mid-run still retains goodput of at least the surviving capacity
//!    fraction minus 10 points over post-fault submissions;
//! 7. **Replan bit-exactness** — quarantining a cluster device and
//!    re-planning onto the survivors reproduces the healthy pool's
//!    logits bit-for-bit, and matches a pool built on the survivor
//!    count from scratch.

use versal_gemm::cluster::Cluster;
use versal_gemm::coordinator::{
    generate, ArrivalKind, Backend, BatchedBackend, ClusterGemmBackend, EchoBackend,
    ServingConfig, ServingReport, ServingRuntime, TenantClass, WorkloadSpec,
};
use versal_gemm::dl::MlpSpec;
use versal_gemm::fault::{FaultEvent, FaultInjector, FaultKind, FaultPlan, RetryPolicy};
use versal_gemm::gemm::Precision;
use versal_gemm::obs::{to_chrome_json, Tracer};
use versal_gemm::util::quickcheck::{prop, Gen};

const IN_DIM: usize = 4;

/// Deterministic backend with a tunable per-row service time — enough
/// load to make a device loss actually hurt, without real GEMM work.
struct SlowBackend {
    cycles_per_row: u64,
}

impl Backend for SlowBackend {
    fn in_dim(&self) -> usize {
        IN_DIM
    }
    fn n_classes(&self) -> usize {
        2
    }
    fn infer_batch(&mut self, batch: usize, x: &[f32]) -> anyhow::Result<(Vec<f32>, u64)> {
        let mut logits = vec![0.0f32; batch * 2];
        for i in 0..batch {
            logits[i * 2] = x[i * IN_DIM];
        }
        Ok((logits, self.cycles_per_row * batch as u64))
    }
}

impl BatchedBackend for SlowBackend {}

fn echo() -> EchoBackend {
    EchoBackend { in_dim: IN_DIM, n_classes: 2 }
}

fn cfg() -> ServingConfig {
    ServingConfig {
        max_batch: 4,
        max_wait_us: 500,
        queue_cap: 32,
        default_slo_us: 50_000,
        cache_budget_bytes: 1 << 20,
        plan_cache_budget_bytes: 1 << 20,
        pipeline_devices: 2,
        max_backlog_us: 10_000,
    }
}

/// (submitted, sum of terminal states) — both per-tenant sums.
fn ledger(r: &ServingReport) -> (u64, u64) {
    let submitted: u64 = r.tenants.iter().map(|t| t.submitted).sum();
    (submitted, r.completed + r.failed + r.expired + r.shed + r.rejected)
}

/// Drive `n` requests at a fixed inter-arrival gap through a runtime,
/// then drain. Returns the runtime for report inspection.
fn drive<B: BatchedBackend>(
    mut rt: ServingRuntime<B>,
    n: usize,
    gap_us: u64,
) -> ServingRuntime<B> {
    let mut now = 0u64;
    for i in 0..n {
        now = i as u64 * gap_us;
        let _ = rt.submit(vec![i as f32, 0.0, 0.0, 0.0], Precision::U8, now);
        rt.tick(now);
    }
    rt.drain(now + 5_000);
    rt
}

/// Property 1: identically-seeded storms replay byte-identically —
/// fingerprint (full metrics registry, wall taint zeroed) and Chrome
/// trace both, across randomized multi-tenant overload workloads.
#[test]
fn seeded_fault_storms_replay_byte_identical() {
    prop("fault-storm-determinism", 0xFA_17_5EED, 4, |g: &mut Gen| {
        let storm_seed = g.rng.next_u64();
        let spec = WorkloadSpec {
            tenants: vec![
                TenantClass::new("gold", 1.0, 3, 10_000 + g.rng.range(0, 20_000) as u64),
                TenantClass::new("free", 2.0, 1, 30_000 + g.rng.range(0, 40_000) as u64),
            ],
            kind: ArrivalKind::Bursty,
            offered_rate: 1_000.0 + g.rng.f64() * 10_000.0,
            burst: 4.0,
            requests: 100,
            seed: g.rng.next_u64(),
        };
        let trace = generate(&spec, IN_DIM);
        let horizon = trace.last().map(|r| r.arrival_us).unwrap_or(1).max(1);
        let plan = FaultPlan::storm(storm_seed, horizon, 2 + g.rng.range(0, 5), 2);
        let run = || {
            let tracer = Tracer::recording();
            let mut rt = ServingRuntime::with_tenants(echo(), cfg(), spec.tenants.clone())
                .with_faults(FaultInjector::new(plan.clone()))
                .with_tracer(tracer.clone());
            rt.replay(&trace);
            (rt.fingerprint(), to_chrome_json(&tracer.snapshot()))
        };
        let (fp_a, tr_a) = run();
        let (fp_b, tr_b) = run();
        if fp_a != fp_b {
            return Err(format!("storm fingerprints diverged:\n{fp_a}\nvs\n{fp_b}"));
        }
        if tr_a != tr_b {
            return Err("storm chrome traces diverged".into());
        }
        Ok(())
    });
}

/// Property 2: an empty fault plan is observationally free — same
/// fingerprint AND same Chrome trace as no injector at all. No fault
/// track is named, no fault metric rows appear, no instants fire.
#[test]
fn zero_fault_plan_is_byte_identical_to_a_fault_free_run() {
    let run = |plan: Option<FaultPlan>| {
        let tracer = Tracer::recording();
        let mut rt = ServingRuntime::new(echo(), cfg()).with_tracer(tracer.clone());
        if let Some(p) = plan {
            rt = rt.with_faults(FaultInjector::new(p));
        }
        let rt = drive(rt, 40, 200);
        (rt.fingerprint(), to_chrome_json(&tracer.snapshot()), rt.report())
    };
    let (fp_plain, tr_plain, rep) = run(None);
    let (fp_empty, tr_empty, rep_empty) = run(Some(FaultPlan::none()));
    assert!(rep.completed > 0, "baseline must serve");
    assert_eq!(fp_plain, fp_empty, "empty plan leaked into the fingerprint");
    assert_eq!(tr_plain, tr_empty, "empty plan leaked into the trace");
    // The report carries the (inactive) injector, but no activity.
    let f = rep_empty.faults.expect("injector attached");
    assert!(!f.activity(), "zero-event plan must report zero activity");
}

/// Property 3: conservation under randomized storms — per tenant and in
/// aggregate, every submission reaches exactly one terminal state, and
/// the aggregate retry counter equals the per-tenant sum (a retry never
/// re-counts a submission).
#[test]
fn conservation_holds_and_retries_never_double_count_under_storms() {
    prop("fault-storm-conservation", 0xC0_4_5EED, 6, |g: &mut Gen| {
        let n_tenants = g.rng.range(1, 4);
        let classes: Vec<TenantClass> = (0..n_tenants)
            .map(|i| {
                TenantClass::new(
                    &format!("t{i}"),
                    0.5 + g.rng.f64() * 3.0,
                    g.rng.range(1, 4) as u8,
                    1_000 + g.rng.range(0, 30_000) as u64,
                )
            })
            .collect();
        let spec = WorkloadSpec {
            tenants: classes.clone(),
            kind: [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Pareto]
                [g.rng.range(0, 3)],
            offered_rate: 500.0 + g.rng.f64() * 30_000.0,
            burst: 4.0,
            requests: 80 + g.rng.range(0, 80),
            seed: g.rng.next_u64(),
        };
        let trace = generate(&spec, IN_DIM);
        let horizon = trace.last().map(|r| r.arrival_us).unwrap_or(1).max(1);
        let plan = FaultPlan::storm(g.rng.next_u64(), horizon, 1 + g.rng.range(0, 6), 2);
        let policy = RetryPolicy {
            max_retries: g.rng.range(0, 4) as u32,
            backoff_us: 100 + g.rng.range(0, 2_000) as u64,
            tenant_retry_budget: g.rng.range(1, 64) as u64,
        };
        let mut rt = ServingRuntime::with_tenants(echo(), cfg(), classes)
            .with_faults(FaultInjector::new(plan).with_policy(policy));
        rt.replay(&trace);
        let r = rt.report();
        let (submitted, terminal) = ledger(&r);
        if submitted != terminal {
            return Err(format!("aggregate leak: {submitted} submitted vs {terminal} terminal"));
        }
        for t in &r.tenants {
            let term = t.completed + t.failed + t.expired + t.shed + t.rejected;
            if t.submitted != term {
                return Err(format!(
                    "tenant {} leak: {} submitted vs {term} terminal",
                    t.name, t.submitted
                ));
            }
        }
        let f = r.faults.expect("injector attached");
        let tenant_retries: u64 = r.tenants.iter().map(|t| t.retries).sum();
        if f.retries != tenant_retries {
            return Err(format!(
                "retry double-count: aggregate {} vs tenant sum {tenant_retries}",
                f.retries
            ));
        }
        if f.retry_exhausted > 0 && r.failed == 0 {
            return Err("exhausted retries must land in `failed`".into());
        }
        Ok(())
    });
}

/// Property 4a: with backoff ≥ SLO every retry would land past the
/// deadline, so none is ever launched — nothing completes, every
/// executed request fails on its first attempt, zero retries fire.
#[test]
fn retry_never_launches_past_the_deadline() {
    let plan = FaultPlan::new(vec![FaultEvent {
        at_us: 0,
        kind: FaultKind::Flaky { every: 1 },
    }]);
    let policy = RetryPolicy { max_retries: 3, backoff_us: 60_000, tenant_retry_budget: 1_024 };
    let rt = drive(
        ServingRuntime::new(echo(), cfg())
            .with_faults(FaultInjector::new(plan).with_policy(policy)),
        24,
        200,
    );
    let r = rt.report();
    assert_eq!(r.completed, 0, "a retry past the deadline must never launch");
    let (submitted, terminal) = ledger(&r);
    assert_eq!(submitted, terminal, "ledger must balance even when everything fails");
    let f = r.faults.expect("injector attached");
    assert_eq!(f.retries, 0, "backoff ≥ SLO admits no retry");
    assert_eq!(f.retry_exhausted, r.failed, "every failure exhausted its (empty) retry room");
}

/// Property 4b: the same all-batches-fail plan with a sane backoff and
/// only every-2nd-batch failing recovers: completions resume, retries
/// fire, and the ledger still balances.
#[test]
fn bounded_retry_recovers_when_backoff_fits_the_deadline() {
    let plan = FaultPlan::new(vec![FaultEvent {
        at_us: 0,
        kind: FaultKind::Flaky { every: 2 },
    }]);
    let policy = RetryPolicy { max_retries: 3, backoff_us: 400, tenant_retry_budget: 1_024 };
    let rt = drive(
        ServingRuntime::new(echo(), cfg())
            .with_faults(FaultInjector::new(plan).with_policy(policy)),
        24,
        200,
    );
    let r = rt.report();
    assert!(r.completed > 0, "service must recover between flaky batches");
    let (submitted, terminal) = ledger(&r);
    assert_eq!(submitted, terminal);
    let f = r.faults.expect("injector attached");
    assert!(f.retries > 0, "failed batches must re-enter forming");
    assert!(f.transient_failures > 0);
}

/// Property 5: a transient batch fault opens a degraded window that the
/// next successful completion closes — recoveries and a cycle-domain
/// MTTR are accounted, and every request still completes.
#[test]
fn transient_fault_recovers_and_accounts_mttr() {
    let plan = FaultPlan::new(vec![FaultEvent {
        at_us: 0,
        kind: FaultKind::Transient { count: 1 },
    }]);
    let rt = drive(
        ServingRuntime::new(echo(), cfg()).with_faults(FaultInjector::new(plan)),
        16,
        200,
    );
    let r = rt.report();
    assert_eq!(r.completed, 16, "one transient fault must not lose requests");
    assert_eq!(r.failed, 0);
    let f = r.faults.expect("injector attached");
    assert_eq!(f.transient_failures, 1);
    assert!(f.retries >= 1, "the failed batch's requests re-entered forming");
    assert!(f.recoveries >= 1, "the degraded window must close");
    assert!(f.mttr_cycles > 0, "recovery takes at least the retry backoff, in cycles");
}

/// Property 6: losing one of two devices mid-run keeps goodput over
/// post-fault submissions at or above the surviving capacity fraction
/// minus 10 points, and the degraded-capacity admission signal fires
/// (the report records the shrunken capacity).
#[test]
fn device_loss_keeps_goodput_above_the_capacity_floor() {
    // ~200 µs of work per request on 2 devices, offered every 150 µs:
    // busy but below the knee while healthy, so the fault is what hurts.
    let rt = drive(
        ServingRuntime::new(SlowBackend { cycles_per_row: 200_000 }, cfg())
            .with_faults(FaultInjector::new(FaultPlan::single_device_loss(1, 2_000))),
        64,
        150,
    );
    let r = rt.report();
    let (submitted, terminal) = ledger(&r);
    assert_eq!(submitted, terminal, "ledger must balance under device loss");
    assert!(r.completed > 0, "the surviving device must keep serving");
    let f = r.faults.expect("injector attached");
    assert_eq!(f.injected, 1);
    assert_eq!(f.first_fault_us, Some(2_000));
    assert!((f.capacity_fraction - 0.5).abs() < 1e-9, "1 of 2 devices survives");
    assert!(f.submitted_after_fault > 0, "the trace extends past the fault");
    let floor = (f.capacity_fraction - 0.10).max(0.0);
    let goodput = f.goodput_after_fault();
    assert!(
        goodput >= floor,
        "goodput after fault {goodput:.3} fell below the capacity floor {floor:.3}"
    );
}

/// Property 7: quarantining a device re-plans bit-exactly — the
/// survivor pool reproduces the healthy logits, and matches a pool of
/// the survivor count built from scratch (same model seed).
#[test]
fn quarantine_replans_bit_exactly_against_the_healthy_pool() {
    let spec = MlpSpec { dims: vec![16, 12, 4] };
    let x: Vec<f32> = (0..3 * 16).map(|i| (i as f32 * 0.31).sin()).collect();

    let mut pool3 = ClusterGemmBackend::new(
        Cluster::vc1902_pool(3, 4).unwrap(),
        spec.clone(),
        7,
    )
    .unwrap();
    let (healthy, _) = pool3.infer_batch(3, &x).unwrap();

    let cost = pool3.quarantine_device(1).unwrap();
    assert!(cost.total() > 0, "recovery is priced in cycles, not free");
    let (degraded, _) = pool3.infer_batch(3, &x).unwrap();
    assert_eq!(healthy, degraded, "replanned logits must be bit-identical to healthy");
    assert_eq!(pool3.cluster().devices.len(), 2, "the failed device left the pool");

    // Same weights served on 2 devices from scratch — the quarantined
    // pool must be indistinguishable from a pool that never saw device 1.
    let mut pool2 =
        ClusterGemmBackend::new(Cluster::vc1902_pool(2, 4).unwrap(), spec, 7).unwrap();
    let (fresh, _) = pool2.infer_batch(3, &x).unwrap();
    assert_eq!(degraded, fresh, "quarantine must converge to the from-scratch plan");
}
