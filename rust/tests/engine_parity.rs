//! Cross-engine parity battery: the work-stealing threads engine must
//! be **bit-identical** to the sequential reference walk — same C bits,
//! same cycle breakdown, same per-tile stats — on every precision,
//! every operand form (dense and prepacked), every pool size, and
//! every shape class, including the degenerate ones.
//!
//! The battery is the pin that makes the pooled engine safe to ship:
//! the deterministic-reduction invariant (each output band applies its
//! compute steps in plan order, so even non-associative bf16/f32
//! accumulation reproduces the sequential association exactly) is
//! asserted here over fuzzed shapes, not just argued in comments.
//!
//! CI runs this file as a named gate across a `PALLAS_POOL_SIZE` ×
//! `PALLAS_PACK_PARALLEL` matrix (pool 1/2/8 × pack-parallel 0/1); when
//! a variable is set the battery pins every pooled run to that value,
//! otherwise it sweeps pool sizes {1, 2, 4, 8} with serial packing.
//! Pooled engines always run arena-backed here, so the recycled-buffer
//! path is pinned bit-exact across the whole battery too. The explicit
//! axis tests below additionally cover pack-parallel on/off and serving
//! fan-out on/off regardless of the environment.

use std::sync::Arc;
use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{RustGemmBackend, ServingConfig, ServingRuntime, TenantClass};
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::precision::Bf16;
use versal_gemm::gemm::{
    prepack_b, BlockedGemm, Ccp, Element, GemmConfig, Mat, ParallelGemm, Precision,
};
use versal_gemm::obs::{to_chrome_json, Tracer};
use versal_gemm::plan::GemmPlan;
use versal_gemm::runtime::pool::POOL_SIZE_ENV;
use versal_gemm::runtime::{pack_parallel_from_env, PackArena, ThreadPool};
use versal_gemm::util::quickcheck::prop;
use versal_gemm::util::Pcg32;
use versal_gemm::VersalArch;

/// Pool sizes under test: the CI matrix pins one via `PALLAS_POOL_SIZE`;
/// an unset variable sweeps the default ladder.
fn pool_sizes() -> Vec<usize> {
    match std::env::var(POOL_SIZE_ENV).ok().and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(n) => vec![n],
        None => vec![1, 2, 4, 8],
    }
}

/// CCP presets the battery draws from: small blocks (many L3/L2 blocks
/// per plan, real parallelism), ragged blocks (edge extents on every
/// loop), and a packing-accounted variant. All are feasible for every
/// precision (2-byte elements included) on the vc1902 hierarchy.
fn presets() -> Vec<GemmConfig> {
    let mut small = GemmConfig::paper_table2(4);
    small.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    let mut ragged = GemmConfig::paper_table2(3);
    ragged.ccp = Ccp { mc: 24, nc: 40, kc: 48 };
    let mut counted = GemmConfig::paper_table2(2);
    counted.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    counted.count_packing = true;
    let mut isolated = GemmConfig::paper_table2(2);
    isolated.ccp = Ccp { mc: 16, nc: 16, kc: 32 };
    isolated.steady_stream = false;
    vec![small, ragged, counted, isolated]
}

/// One full parity case: dense and prepacked, `ParallelGemm` and
/// `BlockedGemm`, sequential vs a `workers`-wide pool. Every comparison
/// is exact equality — bits, cycles, stats.
fn parity_case<T: Element>(
    arch: &VersalArch,
    cfg: &GemmConfig,
    (m, n, k): (usize, usize, usize),
    seed: u64,
    workers: usize,
) -> Result<(), String> {
    let mut rng = Pcg32::new(seed);
    let a = Mat::<T>::random(m, k, &mut rng);
    let b = Mat::<T>::random(k, n, &mut rng);
    let pool = Arc::new(ThreadPool::new(workers));
    // Pooled engines run arena-backed with the pack-parallel mode the
    // CI matrix pins (serial packing when the variable is unset) — the
    // sequential reference stays allocator-plain, so every comparison
    // also pins arena recycling and slice packing bit-invisible.
    let pp = pack_parallel_from_env();
    let arena = Arc::new(PackArena::new());
    let label = |what: &str| {
        format!(
            "{what} diverged: ({m}, {n}, {k}) {} {} workers={workers} pack_parallel={pp}",
            T::PRECISION,
            cfg.ccp
        )
    };

    // --- ParallelGemm, dense ------------------------------------------
    let seq = ParallelGemm::new(arch);
    let pooled = ParallelGemm::new(arch)
        .with_pool(Arc::clone(&pool))
        .with_arena(Arc::clone(&arena))
        .with_pack_parallel(pp);
    let mut c_seq = Mat::<T::Acc>::zeros(m, n);
    let (cy_seq, st_seq) = seq.run_p::<T>(cfg, &a, &b, &mut c_seq).map_err(|e| e.to_string())?;
    let mut c_pool = Mat::<T::Acc>::zeros(m, n);
    let (cy_pool, st_pool) =
        pooled.run_p::<T>(cfg, &a, &b, &mut c_pool).map_err(|e| e.to_string())?;
    if c_seq.data != c_pool.data {
        return Err(label("dense C bits"));
    }
    if cy_seq != cy_pool {
        return Err(label("dense cycle breakdown"));
    }
    if st_seq != st_pool {
        return Err(label("dense tile stats"));
    }

    // --- ParallelGemm, prepacked B (weight-stationary) ----------------
    let pb = prepack_b(&b, cfg.ccp.kc, cfg.ccp.nc);
    let mut cp_seq = Mat::<T::Acc>::zeros(m, n);
    let (pcy_seq, pst_seq) =
        seq.run_prepacked_p::<T>(cfg, &a, &pb, &mut cp_seq).map_err(|e| e.to_string())?;
    let mut cp_pool = Mat::<T::Acc>::zeros(m, n);
    let (pcy_pool, pst_pool) =
        pooled.run_prepacked_p::<T>(cfg, &a, &pb, &mut cp_pool).map_err(|e| e.to_string())?;
    if cp_seq.data != cp_pool.data {
        return Err(label("prepacked C bits"));
    }
    if (pcy_seq, pst_seq) != (pcy_pool, pst_pool) {
        return Err(label("prepacked accounting"));
    }
    // Prepacked and dense walks share numerics by construction.
    if cp_seq.data != c_seq.data {
        return Err(label("prepacked-vs-dense C bits"));
    }

    // --- ParallelGemm, plan-handle prepacked (serving hot path) -------
    let plan = GemmPlan::lower(arch, cfg, m, n, k, T::PRECISION, true)
        .map_err(|e| e.to_string())?;
    let mut cl_seq = Mat::<T::Acc>::zeros(m, n);
    let (lcy_seq, lst_seq) =
        seq.run_prepacked_plan_p::<T>(&plan, &a, &pb, &mut cl_seq).map_err(|e| e.to_string())?;
    let mut cl_pool = Mat::<T::Acc>::zeros(m, n);
    let (lcy_pool, lst_pool) = pooled
        .run_prepacked_plan_p::<T>(&plan, &a, &pb, &mut cl_pool)
        .map_err(|e| e.to_string())?;
    if cl_seq.data != cl_pool.data {
        return Err(label("plan-handle C bits"));
    }
    if (lcy_seq, lst_seq) != (lcy_pool, lst_pool) {
        return Err(label("plan-handle accounting"));
    }

    // --- BlockedGemm (the pedagogical single-tile driver) -------------
    let bseq = BlockedGemm::new(arch);
    let bpooled = BlockedGemm::new(arch)
        .with_pool(Arc::clone(&pool))
        .with_arena(Arc::clone(&arena))
        .with_pack_parallel(pp);
    let mut cb_seq = Mat::<T::Acc>::zeros(m, n);
    let bcy_seq = bseq.run_p::<T>(cfg, &a, &b, &mut cb_seq).map_err(|e| e.to_string())?;
    let mut cb_pool = Mat::<T::Acc>::zeros(m, n);
    let bcy_pool = bpooled.run_p::<T>(cfg, &a, &b, &mut cb_pool).map_err(|e| e.to_string())?;
    if cb_seq.data != cb_pool.data {
        return Err(label("blocked C bits"));
    }
    if bcy_seq != bcy_pool {
        return Err(label("blocked cycle breakdown"));
    }
    Ok(())
}

/// Fuzzed battery over one precision: random shapes, random preset,
/// every pool size under test.
fn fuzz_battery<T: Element>(name: &str, seed: u64, cases: usize) {
    let arch = vc1902();
    let presets = presets();
    let sizes = pool_sizes();
    prop(name, seed, cases, |g| {
        let m = g.dim(48);
        let n = g.dim(48);
        let k = g.dim(96);
        let cfg = &presets[g.rng.range(0, presets.len())];
        let case_seed = g.rng.next_u32() as u64;
        for &w in &sizes {
            parity_case::<T>(&arch, cfg, (m, n, k), case_seed, w)?;
        }
        Ok(())
    });
}

#[test]
fn fuzzed_parity_u8() {
    fuzz_battery::<u8>("engine-parity-u8", 0xE1, 10);
}

#[test]
fn fuzzed_parity_i8() {
    fuzz_battery::<i8>("engine-parity-i8", 0xE2, 8);
}

#[test]
fn fuzzed_parity_i16() {
    fuzz_battery::<i16>("engine-parity-i16", 0xE3, 8);
}

#[test]
fn fuzzed_parity_bf16() {
    // bf16 is the reduction-order canary: f32 accumulation is
    // non-associative, so any completion-order reduction would show
    // up here as flipped low bits.
    fuzz_battery::<Bf16>("engine-parity-bf16", 0xE4, 8);
}

#[test]
fn edge_shapes_parity_all_precisions() {
    // Shapes smaller than one block in every dimension, single-row /
    // single-column problems, exact multiples of the micro-tile, and a
    // single-block plan: the partitioner's clipping and the one-band
    // degenerate chunking all have to agree with the sequential walk.
    let arch = vc1902();
    let mut cfg = GemmConfig::paper_table2(2);
    cfg.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    let shapes = [
        (1, 1, 1),
        (1, 7, 3),
        (5, 1, 9),
        (3, 5, 7),    // everything smaller than MR/NR
        (8, 8, 16),   // exactly one micro-tile
        (32, 32, 64), // exactly one (mc, nc, kc) block
        (9, 33, 65),  // one past each block edge
        (31, 2, 130),
    ];
    for &shape in &shapes {
        for &w in &pool_sizes() {
            parity_case::<u8>(&arch, &cfg, shape, 0xED6E, w).unwrap();
            parity_case::<Bf16>(&arch, &cfg, shape, 0xED6E, w).unwrap();
        }
    }
}

#[test]
fn reduction_order_is_deterministic_across_16_repeats() {
    // The determinism half of the invariant: the same pooled GEMM,
    // repeated, must produce the same bytes every single time — work
    // stealing may schedule bands in any order, but the reduction
    // order (and therefore the output) is pinned by block index. bf16
    // makes any order wobble visible in the low mantissa bits.
    let arch = vc1902();
    let mut cfg = GemmConfig::paper_table2(4);
    cfg.ccp = Ccp { mc: 24, nc: 40, kc: 48 };
    let (m, n, k) = (70, 53, 90);
    let mut rng = Pcg32::new(0xD37);
    let a = Mat::<Bf16>::random(m, k, &mut rng);
    let b = Mat::<Bf16>::random(k, n, &mut rng);

    let seq = ParallelGemm::new(&arch);
    let mut c_ref = Mat::<f32>::zeros(m, n);
    let (cy_ref, _) = seq.run_p::<Bf16>(&cfg, &a, &b, &mut c_ref).unwrap();

    let pooled = ParallelGemm::new(&arch).with_pool(Arc::new(ThreadPool::new(4)));
    for rep in 0..16 {
        let mut c = Mat::<f32>::zeros(m, n);
        let (cy, _) = pooled.run_p::<Bf16>(&cfg, &a, &b, &mut c).unwrap();
        assert_eq!(
            c.data, c_ref.data,
            "repeat {rep}: pooled bf16 result drifted from the sequential reference"
        );
        assert_eq!(cy, cy_ref, "repeat {rep}: cycle accounting drifted");
    }
}

/// Explicit pack-parallel axis: sequential reference vs an arena-backed
/// pooled engine with slice packing forced on or off, two rounds each
/// (the second round executes entirely from recycled arena buffers).
fn pack_parallel_case<T: Element>(
    arch: &VersalArch,
    cfg: &GemmConfig,
    (m, n, k): (usize, usize, usize),
    seed: u64,
    workers: usize,
    pp: bool,
) -> Result<(), String> {
    let mut rng = Pcg32::new(seed);
    let a = Mat::<T>::random(m, k, &mut rng);
    let b = Mat::<T>::random(k, n, &mut rng);
    let label = |what: &str| {
        format!(
            "{what} diverged: ({m}, {n}, {k}) {} workers={workers} pack_parallel={pp}",
            T::PRECISION
        )
    };

    let seq = ParallelGemm::new(arch);
    let mut c_ref = Mat::<T::Acc>::zeros(m, n);
    let (cy_ref, st_ref) = seq.run_p::<T>(cfg, &a, &b, &mut c_ref).map_err(|e| e.to_string())?;
    let pb = prepack_b(&b, cfg.ccp.kc, cfg.ccp.nc);
    let mut cp_ref = Mat::<T::Acc>::zeros(m, n);
    let (pcy_ref, _) =
        seq.run_prepacked_p::<T>(cfg, &a, &pb, &mut cp_ref).map_err(|e| e.to_string())?;

    let pooled = ParallelGemm::new(arch)
        .with_pool(Arc::new(ThreadPool::new(workers)))
        .with_arena(Arc::new(PackArena::new()))
        .with_pack_parallel(pp);
    for round in 0..2 {
        let mut c = Mat::<T::Acc>::zeros(m, n);
        let (cy, st) = pooled.run_p::<T>(cfg, &a, &b, &mut c).map_err(|e| e.to_string())?;
        if c.data != c_ref.data {
            return Err(label(&format!("dense C bits (round {round})")));
        }
        if cy != cy_ref || st != st_ref {
            return Err(label(&format!("dense accounting (round {round})")));
        }
        let mut cp = Mat::<T::Acc>::zeros(m, n);
        let (pcy, _) =
            pooled.run_prepacked_p::<T>(cfg, &a, &pb, &mut cp).map_err(|e| e.to_string())?;
        if cp.data != cp_ref.data || pcy != pcy_ref {
            return Err(label(&format!("prepacked parity (round {round})")));
        }
    }
    Ok(())
}

#[test]
fn pack_parallel_axis_parity_all_precisions() {
    // Both pack-parallel modes, regardless of the CI environment: edge
    // shapes (sub-panel, ragged, edge-block) across pool sizes {1, 2, 8}
    // and all four precisions, with packing cycles counted so the
    // engine-independent accounting fold is exercised too.
    let arch = vc1902();
    let mut cfg = GemmConfig::paper_table2(3);
    cfg.ccp = Ccp { mc: 24, nc: 40, kc: 48 };
    cfg.count_packing = true;
    let shapes = [(3, 5, 7), (37, 29, 70), (33, 65, 9)];
    for &pp in &[false, true] {
        for &w in &[1usize, 2, 8] {
            for &shape in &shapes {
                pack_parallel_case::<u8>(&arch, &cfg, shape, 0xAA1, w, pp).unwrap();
                pack_parallel_case::<i8>(&arch, &cfg, shape, 0xAA2, w, pp).unwrap();
                pack_parallel_case::<i16>(&arch, &cfg, shape, 0xAA3, w, pp).unwrap();
                pack_parallel_case::<Bf16>(&arch, &cfg, shape, 0xAA4, w, pp).unwrap();
            }
        }
    }
}

#[test]
fn fanout_serving_is_byte_identical_to_sequential() {
    // Cross-batch fan-out axis: a three-tenant mixed-precision workload
    // served with and without the fan-out pool must produce identical
    // outcome streams, byte-identical report fingerprints (which fold
    // in the per-tenant ledgers) and byte-identical Chrome traces, at
    // every pool size.
    let arch = vc1902();
    let spec = MlpSpec { dims: vec![16, 12, 4] };
    let classes = || {
        vec![
            TenantClass::new("gold", 1.0, 3, 50_000),
            TenantClass::new("silver", 1.0, 2, 50_000),
            TenantClass::new("free", 2.0, 1, 50_000),
        ]
    };
    let cfg = ServingConfig { max_batch: 2, ..Default::default() };
    let precs = [Precision::U8, Precision::I16, Precision::Bf16];
    let drive = |fanout_workers: Option<usize>| {
        let backend = RustGemmBackend::new(arch.clone(), spec.clone(), 42, 2);
        let tracer = Tracer::recording();
        let mut rt = ServingRuntime::with_tenants(backend, cfg, classes())
            .with_tracer(tracer.clone());
        if let Some(w) = fanout_workers {
            rt = rt.with_fanout(Arc::new(ThreadPool::new(w)));
        }
        for i in 0..18u64 {
            let x: Vec<f32> = (0..16).map(|j| ((i * 16 + j) as f32 * 0.05).sin()).collect();
            rt.submit_for((i % 3) as usize, x, precs[(i % 3) as usize], i).unwrap();
        }
        let mut outs = rt.tick(5_000);
        outs.extend(rt.drain(5_000));
        let view: Vec<_> = outs
            .into_iter()
            .map(|o| (o.tenant, o.precision, o.logits, o.batch_size, o.latency_us))
            .collect();
        (view, rt.fingerprint(), to_chrome_json(&tracer.snapshot()))
    };
    let seq = drive(None);
    for w in [1usize, 2, 8] {
        let fan = drive(Some(w));
        assert_eq!(fan.0, seq.0, "outcomes diverged under fan-out ({w} workers)");
        assert_eq!(fan.1, seq.1, "report fingerprint diverged under fan-out ({w} workers)");
        assert_eq!(fan.2, seq.2, "Chrome trace bytes diverged under fan-out ({w} workers)");
    }
}

#[test]
fn pool_size_env_pins_the_battery_matrix() {
    // The CI gate relies on PALLAS_POOL_SIZE narrowing the sweep to
    // one pinned worker count per matrix leg.
    match std::env::var(POOL_SIZE_ENV).ok().and_then(|v| v.trim().parse::<usize>().ok()) {
        Some(n) => assert_eq!(pool_sizes(), vec![n]),
        None => assert_eq!(pool_sizes(), vec![1, 2, 4, 8]),
    }
}
