//! The disabled tracer's zero-cost contract: executing a plan through
//! an engine holding the default [`Tracer::disabled`] allocates exactly
//! the same bytes as an engine that was never handed a tracer — only a
//! recording tracer pays for span buffering — and neither moves the
//! simulated cycle domain.
//!
//! Pinned with a counting global allocator, like the tuner's streaming
//! O(1)-memory gate in `tuner_streaming.rs`. This file deliberately
//! holds a single `#[test]`: the harness runs tests of one binary
//! concurrently, and a second test would race the global counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::{Ccp, GemmConfig, Mat, ParallelGemm};
use versal_gemm::obs::Tracer;
use versal_gemm::util::Pcg32;

struct CountingAlloc;

static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocated_during(f: impl FnOnce() -> u64) -> (u64, u64) {
    let before = BYTES.load(Ordering::SeqCst);
    let out = f();
    (out, BYTES.load(Ordering::SeqCst) - before)
}

#[test]
fn disabled_tracer_adds_zero_allocations_to_run_plan() {
    let arch = vc1902();
    let mut cfg = GemmConfig::paper_table2(2);
    cfg.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    let (m, n, k) = (64, 48, 128);
    let mut rng = Pcg32::new(0xA110C);
    let a = Mat::<u8>::random(m, k, &mut rng);
    let b = Mat::<u8>::random(k, n, &mut rng);

    let run = |engine: &ParallelGemm<'_>| -> u64 {
        let mut c = Mat::<i32>::zeros(m, n);
        engine.run_p::<u8>(&cfg, &a, &b, &mut c).expect("run").0.total
    };

    let baseline_engine = ParallelGemm::new(&arch);
    let disabled_engine = ParallelGemm::new(&arch).with_tracer(Tracer::disabled());
    let recording = Tracer::recording();
    let recording_engine = ParallelGemm::new(&arch).with_tracer(recording.clone());

    // Warm up lazily-initialised runtime state (thread locals, stdio,
    // ...) so it lands in no measurement.
    let warm = run(&baseline_engine);

    let (base_cycles, base_bytes) = allocated_during(|| run(&baseline_engine));
    let (dis_cycles, dis_bytes) = allocated_during(|| run(&disabled_engine));
    assert_eq!(warm, base_cycles, "the engine is deterministic");
    assert_eq!(base_cycles, dis_cycles, "a tracer must not move the cycle domain");
    assert_eq!(
        dis_bytes, base_bytes,
        "a disabled tracer must be allocation-free on the run_plan hot path: \
         {dis_bytes} B with it vs {base_bytes} B without"
    );

    let (rec_cycles, rec_bytes) = allocated_during(|| run(&recording_engine));
    assert_eq!(
        rec_cycles, base_cycles,
        "a recording tracer must not move the cycle domain either"
    );
    assert!(
        rec_bytes > base_bytes,
        "sanity: recording does buffer spans ({rec_bytes} B !> {base_bytes} B), \
         so the zero-cost comparison above is not vacuous"
    );
    assert!(
        !recording.snapshot().events.is_empty(),
        "the recording run must actually have captured the span stream"
    );
}
