//! Whole-system integration tests that need no AOT artifacts: the
//! coordinator serving the quantised MLP through the simulated parallel
//! GEMM engine, conv-as-GEMM through the blocked driver, and the CLI.

use std::time::Duration;
use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RustGemmBackend,
};
use versal_gemm::dl::conv::{conv_as_gemm, direct_conv, ConvSpec};
use versal_gemm::dl::{Mlp, MlpSpec};
use versal_gemm::gemm::{GemmConfig, MatI32, MatU8, ParallelGemm};
use versal_gemm::util::Pcg32;

#[test]
fn coordinator_serves_mlp_on_simulated_tiles() {
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
            queue_cap: 1024,
        },
        n_workers: 2,
        in_dim: 32,
    };
    let spec = MlpSpec { dims: vec![32, 24, 10] };
    let spec2 = spec.clone();
    let c = Coordinator::start(cfg, move |_| {
        Box::new(RustGemmBackend::new(vc1902(), spec2.clone(), 5, 4))
    });

    let mut rng = Pcg32::new(0xE2E);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    let oracle = Mlp::random(spec, 5);
    for _ in 0..40 {
        let x: Vec<f32> = (0..32).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
        let logits = oracle.forward(1, &x, versal_gemm::gemm::baseline::naive_gemm);
        expected.push(oracle.predict(1, &logits)[0]);
        rxs.push(c.submit(x).unwrap());
    }
    c.flush();
    let mut agree = 0;
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().expect("response");
        assert!(resp.simulated_cycles > 0);
        assert_eq!(resp.logits.len(), 10);
        if resp.predicted_class == want {
            agree += 1;
        }
    }
    // Per-request quantisation in a batch differs from single-sample
    // quantisation (dynamic ranges include batch peers), so rare flips on
    // near-ties are legitimate; demand strong agreement, not identity.
    assert!(agree >= 36, "only {agree}/40 predictions agree with the oracle");
    let m = c.shutdown();
    assert_eq!(m.completed(), 40);
    assert!(m.latency_stats().unwrap().p99_us > 0.0);
}

#[test]
fn conv_layer_through_parallel_engine_matches_direct() {
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut cfg = GemmConfig::paper_table2(4);
    cfg.ccp = versal_gemm::gemm::Ccp { mc: 32, nc: 32, kc: 64 };
    let spec = ConvSpec { c_in: 3, h: 16, w: 16, c_out: 8, kh: 3, kw: 3, stride: 1 };
    let mut rng = Pcg32::new(0xC0);
    let x = MatU8::random(3, 256, &mut rng);
    let kern = MatU8::random(8, 27, &mut rng);
    let got = conv_as_gemm(&spec, &x, &kern, |a, b, c| {
        engine.run(&cfg, a, b, c).map(|_| ()).unwrap();
    });
    let want = direct_conv(&spec, &x, &kern);
    assert_eq!(got.max_abs_diff(&want), 0);
}

#[test]
fn strong_scaling_improves_wall_cycles_monotonically() {
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut rng = Pcg32::new(0x5C);
    let a = MatU8::random(128, 256, &mut rng);
    let b = MatU8::random(256, 128, &mut rng);
    let mut prev = u64::MAX;
    for tiles in [1, 2, 4, 8, 16] {
        let mut cfg = GemmConfig::paper_table2(tiles);
        cfg.ccp = versal_gemm::gemm::Ccp { mc: 128, nc: 128, kc: 256 };
        let mut c = MatI32::zeros(128, 128);
        let (cy, _) = engine.run(&cfg, &a, &b, &mut c).unwrap();
        assert!(cy.total < prev, "tiles={tiles}: {} !< {prev}", cy.total);
        prev = cy.total;
    }
}

#[test]
fn transformer_encoder_through_parallel_engine() {
    // A full encoder block (MHA + FFN) with every projection's MACs on
    // the simulated parallel GEMM — the paper's transformer motivation
    // exercised end to end, verified against the naive-GEMM path.
    use versal_gemm::dl::{AttentionSpec, EncoderBlock};
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut cfg = GemmConfig::paper_table2(4);
    cfg.ccp = versal_gemm::gemm::Ccp { mc: 64, nc: 64, kc: 64 };
    let block = EncoderBlock::random(AttentionSpec::tiny(), 17);
    let seq = 12;
    let x: Vec<f32> = (0..seq * 32).map(|i| ((i as f32) * 0.05).sin()).collect();

    let mut sim_cycles = 0u64;
    let via_engine = block.forward(seq, &x, |a, b, c| {
        let (cy, _) = engine.run(&cfg, a, b, c).expect("gemm");
        sim_cycles += cy.total;
    });
    let via_naive = block.forward(seq, &x, versal_gemm::gemm::baseline::naive_gemm);
    assert_eq!(via_engine, via_naive, "engine and naive GEMM paths agree exactly");
    assert!(sim_cycles > 0);
    assert!(block.macs(seq) > 0);
}

#[test]
fn cli_binary_commands_work() {
    for args in [
        vec!["inspect"],
        vec!["table2", "--tiles", "1,2"],
        vec!["table3"],
        vec!["ccp"],
    ] {
        let code = versal_gemm::cli_main(args.iter().map(|s| s.to_string()).collect());
        assert_eq!(code, 0, "command {args:?}");
    }
}
