//! Trace conformance: the telemetry layer's cross-cutting contracts.
//!
//! 1. `trace_plan`'s traced total equals [`GemmPlan::cost`] bit-for-bit
//!    for every precision — the trace *is* the schedule model's own
//!    timeline, not a parallel estimate that can drift.
//! 2. An actual execution with a recording tracer attached exports
//!    byte-identical Chrome JSON to the pure plan walk — predicted and
//!    executed span streams are the same stream by construction.
//! 3. Serving span trees are well-formed: one track per admitted
//!    request bracketed by `admitted` … `completed`, contiguous
//!    non-overlapping legs, and serialised pipeline stage tracks.
//! 4. Two identically-seeded serving runs export byte-identical traces
//!    (the logical clock and cycle models are the only time sources —
//!    no wall-clock ever reaches the trace bytes).
//! 5. The Chrome export parses with the crate's own JSON reader and
//!    carries all four phases (M metadata, X spans, i instants,
//!    C counters).

use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{FeatureGen, RustGemmBackend, ServingConfig, ServingRuntime};
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::{Ccp, GemmConfig, Mat, ParallelGemm, Precision};
use versal_gemm::obs::{
    to_chrome_json, trace_plan, TraceData, TrackId, Tracer, SERVING_PIPELINE_PID,
    SERVING_REQUEST_PID,
};
use versal_gemm::plan::GemmPlan;
use versal_gemm::util::json::Json;
use versal_gemm::util::Pcg32;

#[test]
fn traced_plan_total_equals_plan_cost_per_precision() {
    let arch = vc1902();
    let mut cfg = GemmConfig::paper_table2(2);
    cfg.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    for prec in Precision::ALL {
        let plan = GemmPlan::lower(&arch, &cfg, 48, 40, 80, prec, false)
            .expect("small shape lowers at the small CCP for every precision");
        let tracer = Tracer::recording();
        let traced = trace_plan(&arch, &plan, &tracer);
        assert_eq!(
            traced,
            plan.cost(&arch).total,
            "{prec}: traced cycles must equal GemmPlan::cost bit-for-bit"
        );
        let data = tracer.snapshot();
        assert!(!data.events.is_empty(), "{prec}: the walk must emit spans");
        for e in &data.events {
            assert!(e.end() >= e.ts, "{prec}: malformed event {e:?}");
        }
    }
}

#[test]
fn executed_trace_matches_plan_trace_byte_for_byte() {
    let arch = vc1902();
    let mut cfg = GemmConfig::paper_table2(2);
    cfg.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    let (m, n, k) = (96, 80, 160);
    let plan = GemmPlan::lower(&arch, &cfg, m, n, k, Precision::U8, false).expect("lowers");
    let predicted = Tracer::recording();
    let traced = trace_plan(&arch, &plan, &predicted);

    let executed = Tracer::recording();
    let engine = ParallelGemm::new(&arch).with_tracer(executed.clone());
    let mut rng = Pcg32::new(0x7ACE);
    let a = Mat::<u8>::random(m, k, &mut rng);
    let b = Mat::<u8>::random(k, n, &mut rng);
    let mut c = Mat::<i32>::zeros(m, n);
    let (cycles, _) = engine.run_p::<u8>(&cfg, &a, &b, &mut c).expect("runs");

    assert_eq!(traced, cycles.total, "traced total must equal executed cycles");
    assert_eq!(
        to_chrome_json(&predicted.snapshot()),
        to_chrome_json(&executed.snapshot()),
        "the plan walk and the execution must emit the identical span stream"
    );
}

/// Drive one deterministic serving session with a recording tracer:
/// 8 single-row requests (a u8/i16 mix) at 50 µs spacing, immediate
/// batch formation, 2 pipeline devices. Returns the captured data and
/// its Chrome export.
fn traced_serve_run(seed: u64) -> (TraceData, String) {
    let spec = MlpSpec { dims: vec![64, 16] };
    let in_dim = spec.dims[0];
    let backend = RustGemmBackend::new(vc1902(), spec, seed, 2);
    let tracer = Tracer::recording();
    let mut rt = ServingRuntime::new(
        backend,
        ServingConfig {
            max_batch: 4,
            max_wait_us: 0,
            queue_cap: 64,
            default_slo_us: 1 << 40,
            cache_budget_bytes: 32 << 20,
            plan_cache_budget_bytes: 4 << 20,
            pipeline_devices: 2,
        },
    )
    .with_tracer(tracer.clone());

    let mut gen = FeatureGen::new(in_dim, seed);
    let mut completed = 0usize;
    for i in 0..8u64 {
        let prec = if i % 3 == 0 { Precision::I16 } else { Precision::U8 };
        rt.submit(gen.next(), prec, i * 50).expect("admit");
        completed += rt.tick(i * 50).len();
    }
    completed += rt.drain(1_000).len();
    assert_eq!(completed, 8, "every request must complete");
    let data = tracer.snapshot();
    let json = to_chrome_json(&data);
    (data, json)
}

#[test]
fn serving_span_trees_are_well_formed() {
    let (data, json) = traced_serve_run(11);

    // One request track per admitted request (tid 0 is the shared
    // admission/cache track), each bracketed admitted … completed with
    // contiguous, non-overlapping latency legs.
    let req_tids: std::collections::BTreeSet<u64> = data
        .events
        .iter()
        .filter(|e| e.track.pid == SERVING_REQUEST_PID && e.track.tid >= 1)
        .map(|e| e.track.tid)
        .collect();
    assert_eq!(req_tids.len(), 8, "one request track per admitted request");
    for tid in req_tids {
        let track = TrackId::new(SERVING_REQUEST_PID, tid);
        let events = data.on_track(track);
        let names: Vec<&str> = events.iter().map(|e| e.name.as_str()).collect();
        assert_eq!(names.first(), Some(&"admitted"), "track {tid}: {names:?}");
        assert_eq!(names.last(), Some(&"completed"), "track {tid}: {names:?}");
        let spans = data.spans_on(track);
        for pair in spans.windows(2) {
            assert!(
                pair[1].ts >= pair[0].end(),
                "track {tid}: request legs must not overlap: {pair:?}"
            );
        }
        let completed_ts = events.last().expect("non-empty").ts;
        if let Some(exec) = spans.iter().find(|e| e.name == "execute") {
            assert_eq!(
                exec.end(),
                completed_ts,
                "track {tid}: the execute leg ends at the completion marker"
            );
        }
    }

    // Pipeline stage tracks (pack engine, transfer, one per device) are
    // serialised timelines: later batches start at or after the stage's
    // previous occupancy ends.
    for tid in [0u64, 1, 2, 3] {
        let spans = data.spans_on(TrackId::new(SERVING_PIPELINE_PID, tid));
        for pair in spans.windows(2) {
            assert!(
                pair[1].ts >= pair[0].end(),
                "pipeline stage tid {tid} overlaps itself: {pair:?}"
            );
        }
    }

    // The export parses with the crate's own JSON reader and carries
    // all four Chrome phases.
    let doc = Json::parse(&json).expect("chrome export must be valid JSON");
    assert_eq!(doc.get("displayTimeUnit").and_then(Json::as_str), Some("ns"));
    let events = doc.get("traceEvents").and_then(Json::as_arr).expect("traceEvents");
    for ph in ["M", "X", "i", "C"] {
        assert!(
            events.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some(ph)),
            "exported trace must contain a {ph:?} phase event"
        );
    }
}

#[test]
fn identically_seeded_serving_runs_export_identical_traces() {
    let (_, first) = traced_serve_run(7);
    let (_, second) = traced_serve_run(7);
    assert_eq!(
        first, second,
        "the trace bytes must be a pure function of the seed — any wall-clock \
         or address-dependent value leaking into the trace breaks this"
    );
}
