//! Golden-model conformance suite for the mixed-precision kernel family.
//!
//! Every precision of the suite (u8, i8, i16, bf16) is driven through the
//! whole engine stack — micro-kernel, sequential blocked driver, parallel
//! loop-L4 driver, and the SUMMA-sharded cluster driver — on randomized
//! shapes *including edge shapes* (m, n, k not multiples of MR/NR/kc) and
//! compared against a naive golden reference:
//!
//! - **u8 / i8 / i16** — bit-exact. Products are exact in the widened
//!   accumulator and integer addition is associative, so any blocking or
//!   sharding must reproduce the reference to the last bit.
//! - **bf16** — checked against an **f64 reference** with a *proven*
//!   forward-error bound. Each bf16·bf16 product is exact in f32 (8-bit
//!   mantissas ⇒ ≤16 product mantissa bits < 24), so the only rounding is
//!   f32 accumulation. A length-L chain of f32 additions of exactly
//!   representable terms satisfies |ŝ − s| ≤ L·u·Σ|terms| with unit
//!   roundoff u = 2⁻²⁴. Along one output element the drivers perform at
//!   most (k−1) in-kernel additions, plus one store-accumulate per
//!   kc-chunk (≤ ⌈k/kc⌉ ≤ k), plus ≤ 2 shard write-backs — bounded by
//!   2k + 4 additions total, giving the bound asserted below.
//!
//! Filtering: set `VERSAL_PRECISION=u8|i8|i16|bf16` (comma-separated) to
//! run one precision's conformance only — CI uses this to make a
//! regression name the offending precision directly.

use versal_gemm::arch::vc1902;
use versal_gemm::cluster::{Cluster, ClusterGemm, ClusterGemmConfig};
use versal_gemm::gemm::baseline::naive_gemm_p;
use versal_gemm::gemm::blocked::BlockedGemm;
use versal_gemm::gemm::{
    bf16_forward_error_bound, Bf16, Ccp, Element, GemmConfig, Mat, ParallelGemm, Precision,
};
use versal_gemm::util::Pcg32;

/// Is `p` selected by the VERSAL_PRECISION env filter (default: all)?
fn enabled(p: Precision) -> bool {
    match std::env::var("VERSAL_PRECISION") {
        Err(_) => true,
        Ok(s) if s.trim().is_empty() => true,
        Ok(s) => s.split(',').any(|t| t.trim().eq_ignore_ascii_case(p.name())),
    }
}

/// Edge shapes: below one panel, just over a panel, primes, kc-straddling.
const EDGE_SHAPES: [(usize, usize, usize); 6] =
    [(13, 17, 9), (7, 64, 5), (41, 23, 31), (1, 1, 1), (3, 3, 3), (19, 100, 25)];

fn cfg(tiles: usize, mc: usize, nc: usize, kc: usize) -> GemmConfig {
    GemmConfig {
        ccp: Ccp { mc, nc, kc },
        tiles,
        count_packing: false,
        steady_stream: true,
    }
}

/// Run one (m, k, n) case at an integer precision T through blocked +
/// parallel + cluster under randomized CCPs and demand bit-exact
/// agreement (|Δ| = 0) with the golden reference. bf16 cases go through
/// `bf16_case` instead, which carries the f64 reference and error bound.
fn integer_case<T: Element>(m: usize, k: usize, n: usize, seed: u64) {
    let arch = vc1902();
    let mut rng = Pcg32::new(seed);
    let a = Mat::<T>::random(m, k, &mut rng);
    let b = Mat::<T>::random(k, n, &mut rng);
    let mut want = Mat::<T::Acc>::zeros(m, n);
    naive_gemm_p::<T>(&a, &b, &mut want);

    // Randomised CCP, deliberately unaligned with the shape.
    let ccp = (rng.range(1, 48), rng.range(1, 48), rng.range(1, 48));

    let blocked = BlockedGemm::new(&arch);
    let mut c1 = Mat::<T::Acc>::zeros(m, n);
    blocked.run_p::<T>(&cfg(1, ccp.0, ccp.1, ccp.2), &a, &b, &mut c1).unwrap();
    assert_eq!(
        c1.max_abs_diff_f64(&want),
        0.0,
        "{} blocked ({m},{k},{n}) ccp {ccp:?}",
        T::PRECISION
    );

    let parallel = ParallelGemm::new(&arch);
    let tiles = rng.range(1, 9);
    let mut c2 = Mat::<T::Acc>::zeros(m, n);
    parallel.run_p::<T>(&cfg(tiles, ccp.0, ccp.1, ccp.2), &a, &b, &mut c2).unwrap();
    assert_eq!(
        c2.max_abs_diff_f64(&want),
        0.0,
        "{} parallel ({m},{k},{n}) tiles {tiles}",
        T::PRECISION
    );

    // Cluster: 2 devices, small shards, SUMMA chunking.
    let cluster = Cluster::vc1902_pool(2, 3).unwrap();
    let engine = ClusterGemm::new(&cluster);
    let mut ccfg = ClusterGemmConfig::with_ccp(Ccp { mc: 16, nc: 16, kc: 32 });
    ccfg.kb = 16;
    let mut c3 = Mat::<T::Acc>::zeros(m, n);
    engine.run_auto_p::<T>(&ccfg, &a, &b, &mut c3).unwrap();
    assert_eq!(
        c3.max_abs_diff_f64(&want),
        0.0,
        "{} cluster ({m},{k},{n})",
        T::PRECISION
    );
}

fn integer_conformance<T: Element>() {
    if !enabled(T::PRECISION) {
        eprintln!("(skipped: VERSAL_PRECISION filters out {})", T::PRECISION);
        return;
    }
    for (i, &(m, k, n)) in EDGE_SHAPES.iter().enumerate() {
        integer_case::<T>(m, k, n, 0x5EED + i as u64);
    }
    // Randomised shapes.
    let mut rng = Pcg32::new(0xC0DE ^ T::PRECISION.elem_bytes());
    for round in 0..12 {
        let m = rng.range(1, 44);
        let k = rng.range(1, 44);
        let n = rng.range(1, 44);
        integer_case::<T>(m, k, n, 0xAB00 + round);
    }
}

#[test]
fn conformance_u8() {
    integer_conformance::<u8>();
}

#[test]
fn conformance_i8() {
    integer_conformance::<i8>();
}

#[test]
fn conformance_i16() {
    integer_conformance::<i16>();
}

/// bf16: f64 golden reference with the proven forward-error bound.
/// Returns (worst observed |Δ|, worst bound) over all elements.
fn bf16_case(m: usize, k: usize, n: usize, seed: u64) {
    let arch = vc1902();
    let mut rng = Pcg32::new(seed);
    let a = Mat::<Bf16>::random(m, k, &mut rng);
    let b = Mat::<Bf16>::random(k, n, &mut rng);
    // f64 reference over the *bf16-rounded* inputs (exact: every bf16
    // value and every product of two is exactly representable in f64),
    // plus the per-element Σ|a·b| the error bound scales with.
    let mut ref64 = vec![0.0f64; m * n];
    let mut sum_abs = vec![0.0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            for p in 0..k {
                let prod = a.at(i, p).to_f32() as f64 * b.at(p, j).to_f32() as f64;
                ref64[i * n + j] += prod;
                sum_abs[i * n + j] += prod.abs();
            }
        }
    }
    let check = |c: &Mat<f32>, label: &str| {
        for i in 0..m {
            for j in 0..n {
                let got = c.at(i, j) as f64;
                let want = ref64[i * n + j];
                let bound = bf16_forward_error_bound(k, sum_abs[i * n + j]) + 1e-30;
                assert!(
                    (got - want).abs() <= bound,
                    "bf16 {label} ({m},{k},{n}) [{i},{j}]: |{got} − {want}| > {bound:.3e}"
                );
            }
        }
    };

    let mut rng2 = Pcg32::new(seed ^ 0xF00D);
    let ccp = (rng2.range(1, 48), rng2.range(1, 48), rng2.range(1, 48));
    let blocked = BlockedGemm::new(&arch);
    let mut c1 = Mat::<f32>::zeros(m, n);
    blocked.run_p::<Bf16>(&cfg(1, ccp.0, ccp.1, ccp.2), &a, &b, &mut c1).unwrap();
    check(&c1, "blocked");

    let parallel = ParallelGemm::new(&arch);
    let mut c2 = Mat::<f32>::zeros(m, n);
    parallel.run_p::<Bf16>(&cfg(4, ccp.0, ccp.1, ccp.2), &a, &b, &mut c2).unwrap();
    check(&c2, "parallel");

    let cluster = Cluster::vc1902_pool(2, 3).unwrap();
    let engine = ClusterGemm::new(&cluster);
    let mut ccfg = ClusterGemmConfig::with_ccp(Ccp { mc: 16, nc: 16, kc: 32 });
    ccfg.kb = 16;
    let mut c3 = Mat::<f32>::zeros(m, n);
    engine.run_auto_p::<Bf16>(&ccfg, &a, &b, &mut c3).unwrap();
    check(&c3, "cluster");
}

#[test]
fn conformance_bf16() {
    if !enabled(Precision::Bf16) {
        eprintln!("(skipped: VERSAL_PRECISION filters out bf16)");
        return;
    }
    for (i, &(m, k, n)) in EDGE_SHAPES.iter().enumerate() {
        bf16_case(m, k, n, 0xBF00 + i as u64);
    }
    let mut rng = Pcg32::new(0xBF16);
    for round in 0..10 {
        let m = rng.range(1, 40);
        let k = rng.range(1, 40);
        let n = rng.range(1, 40);
        bf16_case(m, k, n, 0xBFAB + round);
    }
}

/// The drivers are deterministic at every precision: two identical runs
/// (including the host-threaded parallel path) produce identical bits.
#[test]
fn conformance_runs_are_deterministic() {
    let arch = vc1902();
    let parallel = ParallelGemm::new(&arch);
    if enabled(Precision::I8) {
        let mut rng = Pcg32::new(77);
        let a = Mat::<i8>::random(33, 29, &mut rng);
        let b = Mat::<i8>::random(29, 21, &mut rng);
        let mut c1 = Mat::<i32>::zeros(33, 21);
        let mut c2 = Mat::<i32>::zeros(33, 21);
        parallel.run_p::<i8>(&cfg(4, 16, 16, 16), &a, &b, &mut c1).unwrap();
        parallel.run_p::<i8>(&cfg(4, 16, 16, 16), &a, &b, &mut c2).unwrap();
        assert_eq!(c1, c2);
    }
    if enabled(Precision::Bf16) {
        let mut rng = Pcg32::new(78);
        let a = Mat::<Bf16>::random(24, 31, &mut rng);
        let b = Mat::<Bf16>::random(31, 18, &mut rng);
        let mut c1 = Mat::<f32>::zeros(24, 18);
        let mut c2 = Mat::<f32>::zeros(24, 18);
        parallel.run_p::<Bf16>(&cfg(3, 16, 16, 16), &a, &b, &mut c1).unwrap();
        parallel.run_p::<Bf16>(&cfg(3, 16, 16, 16), &a, &b, &mut c2).unwrap();
        assert_eq!(c1.data, c2.data, "bf16 float path must still be deterministic");
    }
}

/// Satellite: the latent i32 accumulator overflow risk, pinned.
///
/// The safe bound for u8 is k ≤ ⌊i32::MAX / 255²⌋ = 33 025
/// ([`Precision::max_safe_k`]): all-255 operands at exactly that k reach
/// 2 147 450 625 = within 33 022 of i32::MAX without wrapping. The
/// drivers enforce the bound with a debug assertion (test below).
#[test]
fn u8_adversarial_all_255_at_safe_k_bound_is_exact() {
    if !enabled(Precision::U8) {
        return;
    }
    let k = Precision::U8.max_safe_k().unwrap() as usize;
    assert_eq!(k, 33_025);
    let arch = vc1902();
    let a = Mat::<u8>::from_vec(4, k, vec![255; 4 * k]);
    let b = Mat::<u8>::from_vec(k, 4, vec![255; 4 * k]);
    let mut c = Mat::<i32>::zeros(4, 4);
    // kc at the derived maximum (3776): the accumulation crosses many
    // kc-chunks, exercising the store-accumulate path near i32::MAX.
    let blocked = BlockedGemm::new(&arch);
    blocked.run_p::<u8>(&cfg(1, 8, 8, 3776), &a, &b, &mut c).unwrap();
    let want = k as i64 * 255 * 255;
    assert!(want <= i32::MAX as i64);
    assert!(c.data.iter().all(|&v| v as i64 == want), "worst-case sum must not wrap");
}

/// Beyond the safe bound the drivers refuse (debug builds): the debug
/// assertion names the precision and the bound instead of letting the
/// accumulator wrap silently.
#[cfg(debug_assertions)]
#[test]
#[should_panic(expected = "safe accumulation bound")]
fn u8_beyond_safe_k_bound_trips_debug_assertion() {
    if !enabled(Precision::U8) {
        panic!("safe accumulation bound (skipped by VERSAL_PRECISION filter)");
    }
    let k = Precision::U8.max_safe_k().unwrap() as usize + 1;
    let arch = vc1902();
    let a = Mat::<u8>::zeros(4, k);
    let b = Mat::<u8>::zeros(k, 4);
    let mut c = Mat::<i32>::zeros(4, 4);
    let _ = BlockedGemm::new(&arch).run_p::<u8>(&cfg(1, 8, 8, 3776), &a, &b, &mut c);
}

/// i16's worst case overflows i32 by construction but sits far inside
/// the i64 accumulator: the reason the wide path exists.
#[test]
fn i16_adversarial_min_operands_stay_exact_in_i64() {
    if !enabled(Precision::I16) {
        return;
    }
    let k = 4096;
    let arch = vc1902();
    let a = Mat::<i16>::from_vec(8, k, vec![-32768; 8 * k]);
    let b = Mat::<i16>::from_vec(k, 8, vec![-32768; 8 * k]);
    let mut c = Mat::<i64>::zeros(8, 8);
    BlockedGemm::new(&arch).run_p::<i16>(&cfg(1, 8, 8, 1024), &a, &b, &mut c).unwrap();
    let want = k as i64 * 32768 * 32768; // 2^42: > i32::MAX, ≪ i64::MAX
    assert!(want > i32::MAX as i64);
    assert!(c.data.iter().all(|&v| v == want));
}
