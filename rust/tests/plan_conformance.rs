//! Plan-IR conformance: the lowered [`GemmPlan`] is the single loop
//! nest + residency model of the whole stack.
//!
//! Pinned here:
//!
//! 1. **Predicted == executed, structurally and numerically**: the
//!    cycles [`GemmPlan::cost`] prices equal the cycles
//!    [`ParallelGemm::run_p`] / [`ParallelGemm::run_prepacked_p`]
//!    report, per precision, including the tuner's
//!    `predict_cycles_p` entry point — the acceptance criterion of the
//!    plan refactor.
//! 2. **Footprint safety**: for every arch preset × precision, a plan
//!    that lowers successfully keeps every level's peak residency
//!    within its budget and its footprint rows in [`MemLevel::ALL`]
//!    order, and plans that would oversubscribe are construction
//!    errors.
//! 3. **MAC conservation**: plan-executed effective MAC totals equal
//!    [`BlockedGemm::total_macs`] (`m·n·k`) for arbitrary shapes and
//!    CCPs — edge-trimmed extents partition the iteration space.
//! 4. **Numerics unchanged**: plan-driven drivers remain bit-exact
//!    against the naive baseline for the integer precisions.

use versal_gemm::arch::{scaled_acap_2x, vc1902, vck190_arch, MemLevel, VersalArch};
use versal_gemm::gemm::baseline::{naive_gemm, naive_gemm_p};
use versal_gemm::gemm::packing::prepack_b;
use versal_gemm::gemm::precision::Bf16;
use versal_gemm::gemm::{
    tuner, BlockedGemm, Ccp, Element, GemmConfig, Mat, MatI32, MatU8, ParallelGemm, Precision,
};
use versal_gemm::plan::{Buffer, GemmPlan, PlanSpec, PlanStep};
use versal_gemm::util::quickcheck::prop;
use versal_gemm::util::Pcg32;

fn cfg(mc: usize, nc: usize, kc: usize, tiles: usize) -> GemmConfig {
    GemmConfig { ccp: Ccp { mc, nc, kc }, tiles, count_packing: false, steady_stream: true }
}

/// Executed-vs-predicted parity for one precision on an edge shape.
fn parity_case<T: Element>(arch: &VersalArch, seed: u64) {
    let prec = T::PRECISION;
    let engine = ParallelGemm::new(arch);
    let mut rng = Pcg32::new(seed);
    // Edge shape: no dimension divides its stride.
    let (m, k, n) = (21, 45, 27);
    for tiles in [1, 3] {
        let cfg = cfg(16, 16, 32, tiles);
        let a = Mat::<T>::random(m, k, &mut rng);
        let b = Mat::<T>::random(k, n, &mut rng);
        let mut c = Mat::<T::Acc>::zeros(m, n);
        let (executed, _) = engine.run_p::<T>(&cfg, &a, &b, &mut c).unwrap();
        let plan = GemmPlan::lower(arch, &cfg, m, n, k, prec, false).unwrap();
        let predicted = plan.cost(arch);
        assert_eq!(
            executed, predicted,
            "{prec} tiles={tiles}: executed != plan.cost"
        );
        // And the tuner's prediction is the same plan cost.
        assert_eq!(
            tuner::predict_cycles_p(arch, &cfg, m, n, k, prec),
            executed.total,
            "{prec} tiles={tiles}: tuner predicts a different schedule than ran"
        );
    }
}

#[test]
fn plan_cost_equals_executed_cycles_per_precision() {
    let arch = vc1902();
    parity_case::<u8>(&arch, 0x11);
    parity_case::<i8>(&arch, 0x12);
    parity_case::<i16>(&arch, 0x13);
    parity_case::<Bf16>(&arch, 0x14);
}

#[test]
fn plan_cost_parity_includes_counted_packing() {
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut rng = Pcg32::new(0x21);
    let (m, k, n) = (24, 40, 20);
    let mut cfg = cfg(16, 16, 16, 2);
    cfg.count_packing = true;
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let mut c = MatI32::zeros(m, n);
    let (executed, _) = engine.run(&cfg, &a, &b, &mut c).unwrap();
    let plan = GemmPlan::lower(&arch, &cfg, m, n, k, Precision::U8, false).unwrap();
    assert_eq!(executed, plan.cost(&arch));
    assert!(executed.packing > 0, "packing was counted");
    // The tuner now predicts the packing-inclusive schedule too.
    assert_eq!(tuner::predict_cycles_p(&arch, &cfg, m, n, k, Precision::U8), executed.total);
}

#[test]
fn prepacked_plan_cost_equals_executed_warm_path() {
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut rng = Pcg32::new(0x31);
    let (m, k, n) = (21, 45, 27);
    let mut cfg = cfg(16, 16, 32, 3);
    cfg.count_packing = true;
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let pb = prepack_b(&b, cfg.ccp.kc, cfg.ccp.nc);
    let mut c = MatI32::zeros(m, n);
    let (executed, _) = engine.run_prepacked(&cfg, &a, &pb, &mut c).unwrap();
    let warm_plan = GemmPlan::lower(&arch, &cfg, m, n, k, Precision::U8, true).unwrap();
    assert_eq!(executed, warm_plan.cost(&arch), "warm path executes the prepacked plan");
    // The prepacked plan charges strictly less packing than the dense
    // one (the resident Bc blocks are fetches), and the numerics match
    // the dense path bit-exactly.
    let dense_plan = GemmPlan::lower(&arch, &cfg, m, n, k, Precision::U8, false).unwrap();
    assert!(warm_plan.cost(&arch).packing < dense_plan.cost(&arch).packing);
    let mut c2 = MatI32::zeros(m, n);
    engine.run(&cfg, &a, &b, &mut c2).unwrap();
    assert_eq!(c.max_abs_diff(&c2), 0);
}

#[test]
fn plan_driven_drivers_stay_bit_exact_vs_naive() {
    let arch = vc1902();
    let blocked = BlockedGemm::new(&arch);
    let parallel = ParallelGemm::new(&arch);
    let mut rng = Pcg32::new(0x41);
    let (m, k, n) = (37, 53, 29);
    let cfg = cfg(16, 16, 32, 4);
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let mut want = MatI32::zeros(m, n);
    naive_gemm(&a, &b, &mut want);
    let mut c1 = MatI32::zeros(m, n);
    blocked.run(&cfg, &a, &b, &mut c1).unwrap();
    assert_eq!(c1.max_abs_diff(&want), 0, "blocked");
    let mut c2 = MatI32::zeros(m, n);
    parallel.run(&cfg, &a, &b, &mut c2).unwrap();
    assert_eq!(c2.max_abs_diff(&want), 0, "parallel");
    // Signed/wide elements through the same plan walk.
    let a = Mat::<i16>::random(13, 23, &mut rng);
    let b = Mat::<i16>::random(23, 11, &mut rng);
    let mut want = Mat::<i64>::zeros(13, 11);
    naive_gemm_p::<i16>(&a, &b, &mut want);
    let mut c = Mat::<i64>::zeros(13, 11);
    parallel.run_p::<i16>(&cfg, &a, &b, &mut c).unwrap();
    assert_eq!(c.max_abs_diff_f64(&want), 0.0, "i16 parallel");
}

#[test]
fn prop_footprints_fit_capacities_across_presets_and_precisions() {
    let presets: [(&str, fn() -> VersalArch); 3] = [
        ("vc1902", vc1902),
        ("vck190", vck190_arch),
        ("scaled_2x", scaled_acap_2x),
    ];
    for (preset_name, preset) in presets {
        for prec in Precision::ALL {
            let arch = preset();
            prop(
                &format!("plan-footprints-{preset_name}-{prec}"),
                0xF007 ^ prec.elem_bytes(),
                25,
                |g| {
                    let m = g.dim(64);
                    let n = g.dim(64);
                    let k = g.dim(64);
                    let cfg = cfg(
                        g.rng.range(1, 64),
                        g.rng.range(1, 64),
                        g.rng.range(1, 64),
                        g.rng.range(1, 9),
                    );
                    let plan = match GemmPlan::lower(&arch, &cfg, m, n, k, prec, false) {
                        Ok(p) => p,
                        // Infeasible geometry is a legitimate refusal.
                        Err(_) => return Ok(()),
                    };
                    let fps = plan.footprints();
                    if fps.len() != MemLevel::ALL.len() {
                        return Err(format!("{} footprint rows", fps.len()));
                    }
                    for (fp, &level) in fps.iter().zip(MemLevel::ALL.iter()) {
                        if fp.level != level {
                            return Err(format!(
                                "row order: {:?} where {:?} expected",
                                fp.level, level
                            ));
                        }
                        if fp.peak_bytes > fp.budget_bytes() {
                            return Err(format!(
                                "{:?} peak {} exceeds budget {}",
                                fp.level,
                                fp.peak_bytes,
                                fp.budget_bytes()
                            ));
                        }
                        if fp.capacity_bytes != arch.mem_capacity(level) {
                            return Err("capacity drifted from the arch".into());
                        }
                    }
                    // Plan-executed MAC total == BlockedGemm::total_macs.
                    let want = BlockedGemm::total_macs(m, n, k);
                    if plan.total_macs() != want {
                        return Err(format!(
                            "effective MACs {} != m*n*k {}",
                            plan.total_macs(),
                            want
                        ));
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_plan_step_stream_is_well_formed() {
    // Residency discipline: compute only with both buffers resident,
    // packs never double-fill, releases balance packs, and nothing is
    // left resident at the end of the stream.
    let arch = vc1902();
    prop("plan-step-stream", 0x57E9, 60, |g| {
        let m = g.dim(48);
        let n = g.dim(48);
        let k = g.dim(48);
        let cfg = cfg(g.rng.range(1, 48), g.rng.range(1, 48), g.rng.range(1, 48), 1);
        let plan = match GemmPlan::lower(&arch, &cfg, m, n, k, Precision::U8, false) {
            Ok(p) => p,
            Err(_) => return Ok(()),
        };
        let (mut ac_resident, mut bc_resident) = (false, false);
        for (i, step) in plan.steps().iter().enumerate() {
            match step {
                PlanStep::Pack(p) => {
                    let slot = match p.buffer {
                        Buffer::Ac => &mut ac_resident,
                        Buffer::Bc => &mut bc_resident,
                    };
                    if *slot {
                        return Err(format!("step {i}: {} packed twice", p.buffer.name()));
                    }
                    if p.level != p.buffer.level() {
                        return Err(format!("step {i}: wrong destination level"));
                    }
                    if p.bytes == 0 {
                        return Err(format!("step {i}: zero-byte pack"));
                    }
                    *slot = true;
                }
                PlanStep::Compute(_) => {
                    if !(ac_resident && bc_resident) {
                        return Err(format!("step {i}: compute without resident buffers"));
                    }
                }
                PlanStep::Release(r) => {
                    let slot = match r.buffer {
                        Buffer::Ac => &mut ac_resident,
                        Buffer::Bc => &mut bc_resident,
                    };
                    if !*slot {
                        return Err(format!("step {i}: releasing a non-resident buffer"));
                    }
                    *slot = false;
                }
            }
        }
        if ac_resident || bc_resident {
            return Err("buffers left resident at end of plan".into());
        }
        Ok(())
    });
}

#[test]
fn prop_executed_equals_predicted_random_geometry() {
    // The headline property, fuzzed: whatever the shape, CCP, tile
    // count and packing flag, the parallel driver's executed cycles are
    // the plan's predicted cycles.
    let arch = vc1902();
    prop("plan-executed-eq-predicted", 0xE0E1, 25, |g| {
        let m = g.dim(40);
        let n = g.dim(40);
        let k = g.dim(40);
        let mut cfg = cfg(
            g.rng.range(1, 48),
            g.rng.range(1, 48),
            g.rng.range(1, 48),
            g.rng.range(1, 9),
        );
        cfg.count_packing = g.rng.range(0, 2) == 1;
        let a = MatU8::random(m, k, &mut g.rng);
        let b = MatU8::random(k, n, &mut g.rng);
        let mut c = MatI32::zeros(m, n);
        let engine = ParallelGemm::new(&arch);
        let executed = match engine.run(&cfg, &a, &b, &mut c) {
            Ok((cy, _)) => cy,
            Err(e) => return Err(format!("run failed: {e}")),
        };
        let plan = GemmPlan::lower(&arch, &cfg, m, n, k, Precision::U8, false)
            .map_err(|e| e.to_string())?;
        if executed != plan.cost(&arch) {
            return Err(format!(
                "({m},{n},{k}) {}: executed {:?} != predicted {:?}",
                cfg.ccp,
                executed,
                plan.cost(&arch)
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_streamed_steps_equal_materialized_steps() {
    // The streaming refactor's headline property: for arbitrary shapes,
    // CCPs, precisions and the prepacked flag, across every arch
    // preset, the lazy PlanSteps generator emits the *bit-identical*
    // stream the materialized plan holds — and the O(1)-validated spec
    // carries the same footprints and closed-form step counts.
    let presets: [(&str, fn() -> VersalArch); 3] = [
        ("vc1902", vc1902),
        ("vck190", vck190_arch),
        ("scaled_2x", scaled_acap_2x),
    ];
    for (preset_name, preset) in presets {
        for prec in Precision::ALL {
            let arch = preset();
            prop(
                &format!("plan-stream-eq-{preset_name}-{prec}"),
                0x57AE ^ prec.elem_bytes(),
                25,
                |g| {
                    let m = g.dim(64);
                    let n = g.dim(64);
                    let k = g.dim(64);
                    let cfg = cfg(
                        g.rng.range(1, 64),
                        g.rng.range(1, 64),
                        g.rng.range(1, 64),
                        g.rng.range(1, 9),
                    );
                    let prepacked = g.rng.range(0, 2) == 1;
                    let spec = match PlanSpec::new(&arch, &cfg, m, n, k, prec, prepacked) {
                        Ok(s) => s,
                        // Infeasible geometry must refuse identically on
                        // both paths.
                        Err(e) => {
                            let lowered =
                                GemmPlan::lower(&arch, &cfg, m, n, k, prec, prepacked);
                            return match lowered {
                                Err(e2) if e2 == e => Ok(()),
                                Err(e2) => {
                                    Err(format!("error drift: spec {e} vs lower {e2}"))
                                }
                                Ok(_) => Err(format!("spec refused ({e}) but lower ran")),
                            };
                        }
                    };
                    let plan = GemmPlan::lower(&arch, &cfg, m, n, k, prec, prepacked)
                        .map_err(|e| format!("spec validated but lower failed: {e}"))?;
                    let streamed: Vec<PlanStep> = spec.walk().collect();
                    if streamed != plan.steps() {
                        return Err(format!(
                            "({m},{n},{k}) {} prepacked={prepacked}: streamed steps \
                             diverge from materialized",
                            cfg.ccp
                        ));
                    }
                    let replay: Vec<PlanStep> = plan.steps_iter().collect();
                    if replay != plan.steps() {
                        return Err("steps_iter() diverges from steps()".into());
                    }
                    if spec.footprints() != plan.footprints() {
                        return Err("spec footprints diverge from lowered".into());
                    }
                    if spec.n_steps() != plan.steps().len() {
                        return Err(format!(
                            "closed-form n_steps {} != {}",
                            spec.n_steps(),
                            plan.steps().len()
                        ));
                    }
                    if spec.n_compute_steps() != plan.n_compute_steps() {
                        return Err("closed-form compute count drifted".into());
                    }
                    if spec.total_macs() != plan.total_macs() {
                        return Err("closed-form MACs drifted".into());
                    }
                    Ok(())
                },
            );
        }
    }
}

#[test]
fn prop_streaming_cost_equals_materialized_cost() {
    // The tuner's allocation-free fold prices bit-identically to the
    // materialized plan, across shapes, CCPs, tile counts, the packing
    // flag and the prepacked flag.
    let arch = vc1902();
    prop("plan-streaming-cost-eq", 0xC057, 50, |g| {
        let m = g.dim(48);
        let n = g.dim(48);
        let k = g.dim(48);
        let mut cfg = cfg(
            g.rng.range(1, 48),
            g.rng.range(1, 48),
            g.rng.range(1, 48),
            g.rng.range(1, 9),
        );
        cfg.count_packing = g.rng.range(0, 2) == 1;
        let prepacked = g.rng.range(0, 2) == 1;
        let prec = Precision::ALL[g.rng.range(0, 4)];
        let spec = match PlanSpec::new(&arch, &cfg, m, n, k, prec, prepacked) {
            Ok(s) => s,
            Err(_) => return Ok(()),
        };
        let plan = GemmPlan::lower(&arch, &cfg, m, n, k, prec, prepacked)
            .map_err(|e| e.to_string())?;
        let streaming = spec.cost_streaming(&arch);
        let materialized = plan.cost(&arch);
        if streaming != materialized {
            return Err(format!(
                "({m},{n},{k}) {prec} {} count_packing={} prepacked={prepacked}: \
                 streaming {streaming:?} != materialized {materialized:?}",
                cfg.ccp, cfg.count_packing
            ));
        }
        // And the tuner's public entry point reports the same total for
        // the dense case it predicts.
        if !prepacked
            && tuner::predict_cycles_p(&arch, &cfg, m, n, k, prec) != materialized.total
        {
            return Err("tuner prediction drifted from plan cost".into());
        }
        Ok(())
    });
}

#[test]
fn cluster_shard_plans_match_device_execution() {
    // The cluster scheduler lowers one plan per shard; its schedule
    // must equal the real sharded run (also pinned inside the cluster
    // suite — asserted here through the public API for the plan's sake).
    use versal_gemm::cluster::{Cluster, ClusterGemm, ClusterGemmConfig};
    let cluster = Cluster::vc1902_pool(4, 3).unwrap();
    let engine = ClusterGemm::new(&cluster);
    let mut rng = Pcg32::new(0x61);
    let (m, n, k) = (40, 36, 64);
    let ccfg = ClusterGemmConfig::with_ccp(Ccp { mc: 16, nc: 16, kc: 32 });
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let mut c = MatI32::zeros(m, n);
    let placement =
        versal_gemm::cluster::GridPlacement::auto(&cluster, m, n).unwrap();
    let (ran, _) = engine.run(&ccfg, &placement, &a, &b, &mut c).unwrap();
    let planned = engine.schedule(&ccfg, &placement, m, n, k).unwrap();
    assert_eq!(ran, planned, "cluster schedule == cluster run through shard plans");
}
