//! Overload property battery for the multi-tenant serving runtime.
//!
//! Every invariant the stress lab depends on is pinned here, mostly as
//! randomized properties over the mini harness
//! (`versal_gemm::util::quickcheck`):
//!
//! 1. **Determinism** — identical workload specs replay to
//!    byte-identical report fingerprints and byte-identical Chrome
//!    traces, across every arrival-process family;
//! 2. **Conservation** — per tenant, every submitted request is
//!    accounted exactly once: completed + failed + expired + shed +
//!    rejected;
//! 3. **Priority monotonicity** — with identical arrivals, the
//!    higher-priority of two otherwise-identical tenants never ends up
//!    with less goodput, regardless of tenant index;
//! 4. **Graceful degradation** — far past the saturation knee, shedding
//!    hits the lowest priority hardest and the gold tenant's p99 stays
//!    within its SLO (execution backpressure keeps the execute leg
//!    bounded);
//! 5. **Cache-partition isolation** — a storming tenant's evictions
//!    never touch another tenant's partition counters or residency;
//! 6. **Engine parity** — the pooled (threads-engine) GEMM backend
//!    replays to a report fingerprint and a Chrome trace byte-identical
//!    to the sequential backend on the same seeded workload.

use std::sync::Arc;
use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    generate, ArrivalKind, Backend, BatchedBackend, EchoBackend, GenRequest, RustGemmBackend,
    ServingConfig, ServingRuntime, TenantClass, WorkloadSpec,
};
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::Precision;
use versal_gemm::obs::{to_chrome_json, Tracer};
use versal_gemm::runtime::ThreadPool;
use versal_gemm::util::quickcheck::{prop, Gen};

const IN_DIM: usize = 4;

/// A deterministic backend with a tunable per-row service time, for
/// driving the runtime deep into overload without real GEMM work.
struct SlowBackend {
    cycles_per_row: u64,
}

impl Backend for SlowBackend {
    fn in_dim(&self) -> usize {
        IN_DIM
    }
    fn n_classes(&self) -> usize {
        2
    }
    fn infer_batch(&mut self, batch: usize, x: &[f32]) -> anyhow::Result<(Vec<f32>, u64)> {
        let mut logits = vec![0.0f32; batch * 2];
        for i in 0..batch {
            logits[i * 2] = x[i * IN_DIM];
        }
        Ok((logits, self.cycles_per_row * batch as u64))
    }
}

impl BatchedBackend for SlowBackend {}

fn echo() -> EchoBackend {
    EchoBackend { in_dim: IN_DIM, n_classes: 2 }
}

fn all_kinds() -> [ArrivalKind; 5] {
    [
        ArrivalKind::Poisson,
        ArrivalKind::Uniform,
        ArrivalKind::Bursty,
        ArrivalKind::Pareto,
        ArrivalKind::Diurnal,
    ]
}

/// Property 1: identical specs ⇒ byte-identical fingerprints and
/// byte-identical Chrome traces, for every arrival family. The
/// fingerprint covers the full metrics registry (wall-clock taint
/// zeroed), so any nondeterminism anywhere in admission, forming,
/// execution or accounting trips this.
#[test]
fn determinism_identical_seeds_fingerprint_and_trace() {
    prop("overload-determinism", 0xD57E_2211, 3, |g: &mut Gen| {
        let kind = all_kinds()[g.rng.range(0, 5)];
        let spec = WorkloadSpec {
            tenants: vec![
                TenantClass::new("gold", 1.0, 3, 5_000 + g.rng.range(0, 20_000) as u64),
                TenantClass::new("free", 3.0, 1, 20_000 + g.rng.range(0, 80_000) as u64),
            ],
            kind,
            offered_rate: 500.0 + g.rng.f64() * 50_000.0,
            burst: 4.0,
            requests: 120,
            seed: g.rng.next_u64(),
        };
        let trace = generate(&spec, IN_DIM);
        let run = |trace: &[GenRequest]| {
            let tracer = Tracer::recording();
            let mut rt = ServingRuntime::with_tenants(
                echo(),
                ServingConfig {
                    max_batch: 4,
                    max_wait_us: 500,
                    queue_cap: 32,
                    default_slo_us: 50_000,
                    cache_budget_bytes: 1 << 20,
                    plan_cache_budget_bytes: 1 << 20,
                    pipeline_devices: 2,
                    max_backlog_us: 10_000,
                },
                spec.tenants.clone(),
            )
            .with_tracer(tracer.clone());
            rt.replay(trace);
            (rt.fingerprint(), to_chrome_json(&tracer.snapshot()))
        };
        let (fp_a, trace_a) = run(&trace);
        let (fp_b, trace_b) = run(&trace);
        if fp_a != fp_b {
            return Err(format!("{kind:?}: fingerprints diverged:\n{fp_a}\nvs\n{fp_b}"));
        }
        if trace_a != trace_b {
            return Err(format!("{kind:?}: chrome traces diverged"));
        }
        // The trace itself must also regenerate byte-identically.
        let regen = generate(&spec, IN_DIM);
        if trace.len() != regen.len()
            || trace
                .iter()
                .zip(&regen)
                .any(|(x, y)| x.arrival_us != y.arrival_us || x.tenant != y.tenant)
        {
            return Err(format!("{kind:?}: regenerated trace diverged"));
        }
        Ok(())
    });
}

/// Property 2: per tenant, submitted = completed + failed + expired +
/// shed + rejected after a drain — nothing is double-counted and
/// nothing vanishes, across randomized queue caps, batch policies,
/// tenant sets and overload levels, with caller errors mixed in.
#[test]
fn conservation_every_submission_accounted_once() {
    prop("overload-conservation", 0xC0_5E4E, 8, |g: &mut Gen| {
        let n_tenants = g.rng.range(1, 4);
        let classes: Vec<TenantClass> = (0..n_tenants)
            .map(|i| {
                TenantClass::new(
                    &format!("t{i}"),
                    0.5 + g.rng.f64() * 4.0,
                    g.rng.range(1, 4) as u8,
                    // Some SLOs tight enough to expire in-queue work.
                    500 + g.rng.range(0, 30_000) as u64,
                )
            })
            .collect();
        let spec = WorkloadSpec {
            tenants: classes.clone(),
            kind: all_kinds()[g.rng.range(0, 5)],
            offered_rate: 2_000.0 + g.rng.f64() * 200_000.0,
            burst: 1.0 + g.rng.f64() * 7.0,
            requests: 150,
            seed: g.rng.next_u64(),
        };
        let trace = generate(&spec, IN_DIM);
        let mut rt = ServingRuntime::with_tenants(
            echo(),
            ServingConfig {
                max_batch: g.rng.range(1, 9),
                max_wait_us: g.rng.range(0, 2_001) as u64,
                queue_cap: g.rng.range(4, 33),
                default_slo_us: 50_000,
                cache_budget_bytes: 1 << 20,
                plan_cache_budget_bytes: 1 << 20,
                pipeline_devices: 1 + g.rng.range(0, 3),
                max_backlog_us: [u64::MAX, 2_000][g.rng.range(0, 2)],
            },
            classes,
        );
        let (_, end) = rt.replay(&trace);
        // Caller errors must join the ledger too: a bad shape counts as
        // rejected for its tenant; an unknown tenant is rejected only in
        // the aggregate (no tenant row exists to charge).
        let _ = rt.submit_for(0, vec![0.0; IN_DIM + 1], Precision::U8, end);
        let _ = rt.submit_for(n_tenants + 5, vec![0.0; IN_DIM], Precision::U8, end);
        rt.drain(end);

        let rep = rt.report();
        if rt.queued() != 0 {
            return Err(format!("{} requests still queued after drain", rt.queued()));
        }
        let mut total_submitted = 0u64;
        for t in &rep.tenants {
            let accounted = t.completed + t.failed + t.expired + t.shed + t.rejected;
            if t.submitted != accounted {
                return Err(format!(
                    "tenant {}: submitted {} != completed {} + failed {} + expired {} + \
                     shed {} + rejected {}",
                    t.name, t.submitted, t.completed, t.failed, t.expired, t.shed, t.rejected
                ));
            }
            total_submitted += t.submitted;
        }
        // The aggregate ledger closes as well, including the
        // unknown-tenant rejection no tenant row saw.
        let aggregate = rep.completed + rep.failed + rep.expired + rep.shed + rep.rejected;
        if total_submitted + 1 != aggregate {
            return Err(format!(
                "aggregate: tenants submitted {total_submitted} + 1 unknown-tenant != \
                 completed {} + failed {} + expired {} + shed {} + rejected {}",
                rep.completed, rep.failed, rep.expired, rep.shed, rep.rejected
            ));
        }
        Ok(())
    });
}

/// Replay a hand-built trace of paired arrivals (both tenants get a
/// request at the same instant) through a two-tenant runtime and return
/// each tenant's goodput (completions within SLO).
fn paired_overload_run(priorities: [u8; 2], seed: u64, requests: usize) -> [u64; 2] {
    let slo_us = 60_000;
    let classes = vec![
        TenantClass::new("a", 1.0, priorities[0], slo_us),
        TenantClass::new("b", 1.0, priorities[1], slo_us),
    ];
    // ~6x overload: 0.2 ms/row service against paired arrivals every
    // 65 µs (≈ 30k rows/s offered vs ≈ 5k rows/s capacity).
    let backend = SlowBackend { cycles_per_row: 200_000 };
    let mut rt = ServingRuntime::with_tenants(
        backend,
        ServingConfig {
            max_batch: 4,
            max_wait_us: 500,
            queue_cap: 24,
            default_slo_us: slo_us,
            cache_budget_bytes: 1 << 20,
            plan_cache_budget_bytes: 1 << 20,
            pipeline_devices: 2,
            max_backlog_us: 10_000,
        },
        classes,
    );
    let mut now = 0u64;
    let mut phase = seed;
    let trace: Vec<GenRequest> = (0..requests)
        .flat_map(|_| {
            // Deterministic jittered gap from the seed (splitmix-style),
            // identical whichever tenant holds the higher priority.
            phase = phase.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            now += 40 + (phase >> 59); // 40..72 µs
            let f = (phase >> 32) as f32 / u32::MAX as f32;
            [0usize, 1usize].map(|t| GenRequest {
                tenant: t,
                arrival_us: now,
                precision: Precision::U8,
                features: vec![f; IN_DIM],
            })
        })
        .collect();
    rt.replay(&trace);
    let rep = rt.report();
    [rep.tenants[0].completed_in_slo, rep.tenants[1].completed_in_slo]
}

/// Property 3: under identical arrivals, raising a tenant's priority
/// never lowers its goodput — in either tenant-index orientation, so
/// the queue's index tie-break cannot masquerade as priority.
#[test]
fn priority_monotonicity_under_overload() {
    prop("overload-priority-monotonicity", 0x9121_07, 5, |g: &mut Gen| {
        let seed = g.rng.next_u64();
        let requests = 120 + g.rng.range(0, 80);
        // Orientation 1: tenant 0 holds the high priority.
        let hi_first = paired_overload_run([3, 1], seed, requests);
        if hi_first[0] < hi_first[1] {
            return Err(format!(
                "tenant 0 at priority 3 got less goodput than tenant 1 at 1: {hi_first:?}"
            ));
        }
        // Orientation 2: tenant 1 holds it (beats the index tie-break).
        let hi_second = paired_overload_run([1, 3], seed, requests);
        if hi_second[1] < hi_second[0] {
            return Err(format!(
                "tenant 1 at priority 3 got less goodput than tenant 0 at 1: {hi_second:?}"
            ));
        }
        // And the high-priority seat itself is worth something: in at
        // least one orientation it strictly beats the low seat (a
        // scheduler that ignored priority entirely would tie both).
        if hi_first[0] == hi_first[1] && hi_second[0] == hi_second[1] {
            return Err(format!(
                "priority never changed goodput under 6x overload: {hi_first:?} / {hi_second:?}"
            ));
        }
        Ok(())
    });
}

/// Invariant 4: far past the knee, degradation is graceful — shedding
/// is ordered lowest-priority-first and the gold tenant's p99 stays
/// within its SLO because execution backpressure bounds the execute
/// leg. Deterministic scenario (gold:silver:free = 1:8:23 at ~6x the
/// backend's capacity), the same shape as `bench_serving`'s sweep.
#[test]
fn graceful_degradation_past_the_knee() {
    let gold_slo_us = 100_000;
    let classes = vec![
        TenantClass::new("gold", 1.0, 3, gold_slo_us),
        TenantClass::new("silver", 8.0, 2, 4 * gold_slo_us),
        TenantClass::new("free", 23.0, 1, 16 * gold_slo_us),
    ];
    // 1 ms/row ⇒ capacity ≈ 1 000 rows/s; offered 6 000/s.
    let backend = SlowBackend { cycles_per_row: 1_000_000 };
    let mut rt = ServingRuntime::with_tenants(
        backend,
        ServingConfig {
            max_batch: 8,
            max_wait_us: 1_000,
            queue_cap: 64,
            default_slo_us: gold_slo_us,
            cache_budget_bytes: 1 << 20,
            plan_cache_budget_bytes: 1 << 20,
            pipeline_devices: 2,
            max_backlog_us: 20_000,
        },
        classes.clone(),
    );
    let trace = generate(
        &WorkloadSpec {
            tenants: classes,
            kind: ArrivalKind::Poisson,
            offered_rate: 6_000.0,
            burst: 1.0,
            requests: 400,
            seed: 20_26,
        },
        IN_DIM,
    );
    rt.replay(&trace);
    let rep = rt.report();
    let [gold, silver, free] = [&rep.tenants[0], &rep.tenants[1], &rep.tenants[2]];

    assert!(rep.shed > 0, "6x overload against a 64-deep queue must shed");
    assert!(
        gold.shed_rate() <= silver.shed_rate() && silver.shed_rate() <= free.shed_rate(),
        "shedding must hit the lowest priority hardest: gold {:.3} silver {:.3} free {:.3}",
        gold.shed_rate(),
        silver.shed_rate(),
        free.shed_rate()
    );
    assert!(free.shed_rate() > 0.0, "the free tier must carry shed load");
    let gold_p99 = gold.latency.as_ref().expect("gold completed work").p99_us;
    assert!(
        gold_p99 <= gold_slo_us as f64,
        "gold p99 {gold_p99:.0} µs must stay within its {gold_slo_us} µs SLO past the knee"
    );
    assert!(
        gold.goodput_rate() > 0.9,
        "gold demand (≈ 0.2x capacity) fits; its goodput must survive overload: {:.3}",
        gold.goodput_rate()
    );
}

/// Invariant 5: cache partitions isolate tenants — a storming tenant
/// churning its own partition leaves the other tenant's counters,
/// residency and hit path untouched.
#[test]
fn cache_partition_isolation_under_storm() {
    let spec = MlpSpec { dims: vec![16, 12, 4] };
    let classes = vec![
        TenantClass::new("steady", 1.0, 2, 1_000_000),
        TenantClass::new("stormy", 1.0, 1, 1_000_000),
    ];
    // Partition budgets sized so the storm overflows its own packed
    // partition: each tenant gets 1 KiB; the steady tenant's u8 set
    // (two packed layers, ≈ 350 B) fits, the storm's three-precision
    // set (≈ 1.7 KiB) cannot co-reside.
    let backend = RustGemmBackend::new(versal_gemm::arch::vc1902(), spec.clone(), 5, 4);
    let mut rt = ServingRuntime::with_tenants(
        backend,
        ServingConfig {
            max_batch: 2,
            max_wait_us: 0,
            queue_cap: 64,
            default_slo_us: 1_000_000,
            cache_budget_bytes: 2 << 10,
            plan_cache_budget_bytes: 1 << 20,
            pipeline_devices: 1,
            max_backlog_us: u64::MAX,
        },
        classes,
    );
    let x = vec![0.25f32; 16];

    // Warm the steady tenant and snapshot its partition.
    rt.submit_for(0, x.clone(), Precision::U8, 0).unwrap();
    rt.drain(0);
    rt.submit_for(0, x.clone(), Precision::U8, 10).unwrap();
    rt.drain(10);
    let before = rt.report().tenants[0].cache;
    assert!(before.hits > 0, "warm steady tenant hits its own partition");
    assert_eq!(before.evictions, 0, "steady working set fits its partition");

    // Storm the other tenant across precisions to force evictions in
    // its partition only.
    for (i, prec) in [Precision::U8, Precision::I16, Precision::Bf16, Precision::U8]
        .iter()
        .cycle()
        .take(12)
        .enumerate()
    {
        rt.submit_for(1, x.clone(), *prec, 100 + i as u64).unwrap();
        rt.drain(100 + i as u64);
    }
    let after = rt.report();
    assert!(
        after.tenants[1].cache.evictions > 0,
        "the storm must overflow the stormy partition (else the test proves nothing): {:?}",
        after.tenants[1].cache
    );
    let steady = after.tenants[0].cache;
    assert_eq!(
        (steady.hits, steady.misses, steady.evictions, steady.bytes),
        (before.hits, before.misses, before.evictions, before.bytes),
        "the storm must not touch the steady tenant's partition counters"
    );

    // And the steady tenant's residency survived: the next request
    // still hits.
    rt.submit_for(0, x, Precision::U8, 1_000).unwrap();
    rt.drain(1_000);
    let final_steady = rt.report().tenants[0].cache;
    assert!(
        final_steady.hits > before.hits && final_steady.misses == before.misses,
        "steady tenant still hits after the storm: {final_steady:?} vs {before:?}"
    );
}

/// Property 6: the pooled (threads-engine) GEMM backend is
/// indistinguishable from the sequential backend at the serving
/// surface — byte-identical report fingerprint AND byte-identical
/// Chrome trace on the same seeded multi-tenant workload, for every
/// pool width. Host scheduling must never leak into the cycle domain:
/// the deterministic reduction pins the numerics, and the accounting
/// fold replays the same step-carried costs either way.
#[test]
fn pooled_backend_fingerprint_and_trace_match_sequential() {
    let spec = MlpSpec { dims: vec![64, 8] };
    let classes = vec![
        TenantClass::new("gold", 1.0, 3, 50_000),
        TenantClass::new("free", 3.0, 1, 200_000),
    ];
    let workload = WorkloadSpec {
        tenants: classes.clone(),
        kind: ArrivalKind::Bursty,
        offered_rate: 30_000.0,
        burst: 4.0,
        requests: 48,
        seed: 0xF1A6,
    };
    let trace = generate(&workload, spec.dims[0]);
    let run = |pool: Option<Arc<ThreadPool>>| {
        let mut backend = RustGemmBackend::new(vc1902(), spec.clone(), 11, 4);
        if let Some(p) = pool {
            backend = backend.with_pool(p);
        }
        let tracer = Tracer::recording();
        let mut rt = ServingRuntime::with_tenants(
            backend,
            ServingConfig {
                max_batch: 4,
                max_wait_us: 500,
                queue_cap: 64,
                default_slo_us: 100_000,
                cache_budget_bytes: 8 << 20,
                plan_cache_budget_bytes: 1 << 20,
                pipeline_devices: 2,
                max_backlog_us: 20_000,
            },
            classes.clone(),
        )
        .with_tracer(tracer.clone());
        rt.replay(&trace);
        (rt.fingerprint(), to_chrome_json(&tracer.snapshot()))
    };
    let (fp_seq, trace_seq) = run(None);
    for workers in [1usize, 4, 8] {
        let (fp, tr) = run(Some(Arc::new(ThreadPool::new(workers))));
        assert_eq!(
            fp, fp_seq,
            "{workers}-worker pooled fingerprint diverged from the sequential backend"
        );
        assert_eq!(tr, trace_seq, "{workers}-worker pooled chrome trace diverged");
    }
}

/// Property 7: overload invariants survive a concurrent fault storm.
/// Faults compose with priority shedding — the conservation ledger
/// still balances per tenant (retries never re-count a submission),
/// identically-seeded runs stay byte-identical, and no tenant ever
/// exceeds its lifetime retry budget, however the storm lands.
#[test]
fn fault_storm_composes_with_overload_shedding() {
    use versal_gemm::fault::{FaultInjector, FaultPlan, RetryPolicy};
    prop("overload-x-faults", 0x0F_F10AD, 4, |g: &mut Gen| {
        let classes = vec![
            TenantClass::new("gold", 1.0, 3, 20_000),
            TenantClass::new("free", 1.0, 1, 20_000),
        ];
        let spec = WorkloadSpec {
            tenants: classes.clone(),
            kind: all_kinds()[g.rng.range(0, 5)],
            // Past the knee for the slow backend: shedding is active.
            offered_rate: 3_000.0 + g.rng.f64() * 9_000.0,
            burst: 4.0,
            requests: 150,
            seed: g.rng.next_u64(),
        };
        let trace = generate(&spec, IN_DIM);
        let horizon = trace.last().map(|r| r.arrival_us).unwrap_or(1).max(1);
        let plan = FaultPlan::storm(g.rng.next_u64(), horizon, 3, 2);
        let run = || {
            let mut rt = ServingRuntime::with_tenants(
                SlowBackend { cycles_per_row: 400_000 },
                ServingConfig {
                    max_batch: 4,
                    max_wait_us: 500,
                    queue_cap: 16,
                    default_slo_us: 20_000,
                    cache_budget_bytes: 1 << 20,
                    plan_cache_budget_bytes: 1 << 20,
                    pipeline_devices: 2,
                    max_backlog_us: 10_000,
                },
                classes.clone(),
            )
            .with_faults(FaultInjector::new(plan.clone()).with_policy(RetryPolicy {
                max_retries: 2,
                backoff_us: 300,
                tenant_retry_budget: 32,
            }));
            rt.replay(&trace);
            (rt.fingerprint(), rt.report())
        };
        let (fp_a, r) = run();
        let (fp_b, _) = run();
        if fp_a != fp_b {
            return Err("storm-under-overload fingerprints diverged".into());
        }
        let submitted: u64 = r.tenants.iter().map(|t| t.submitted).sum();
        let terminal = r.completed + r.failed + r.expired + r.shed + r.rejected;
        if submitted != terminal {
            return Err(format!("ledger leak: {submitted} submitted vs {terminal} terminal"));
        }
        for t in &r.tenants {
            let term = t.completed + t.failed + t.expired + t.shed + t.rejected;
            if t.submitted != term {
                return Err(format!("tenant {} leak under storm+overload", t.name));
            }
        }
        let f = r.faults.expect("injector attached");
        let tenant_retries: u64 = r.tenants.iter().map(|t| t.retries).sum();
        if f.retries != tenant_retries {
            return Err(format!(
                "retries double-counted under overload: {} vs {tenant_retries}",
                f.retries
            ));
        }
        // The retry budget is a hard cap per tenant, storm or not.
        for t in &r.tenants {
            if t.retries > 32 {
                return Err(format!("tenant {} blew its retry budget: {}", t.name, t.retries));
            }
        }
        Ok(())
    });
}
