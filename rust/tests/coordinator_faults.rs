//! Failure-injection tests for the serving coordinator: flaky backends,
//! panicking-workload shapes, saturation, and shutdown races.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;
use versal_gemm::coordinator::{
    Backend, BatcherConfig, Coordinator, CoordinatorConfig,
};

/// Backend that errors on every Nth batch.
struct FlakyBackend {
    counter: Arc<AtomicUsize>,
    fail_every: usize,
}

impl Backend for FlakyBackend {
    fn in_dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        2
    }
    fn infer_batch(&mut self, batch: usize, x: &[f32]) -> anyhow::Result<(Vec<f32>, u64)> {
        let n = self.counter.fetch_add(1, Ordering::SeqCst) + 1;
        if n % self.fail_every == 0 {
            anyhow::bail!("injected failure on batch {n}");
        }
        let mut logits = vec![0.0f32; batch * 2];
        for i in 0..batch {
            logits[i * 2] = x[i * 2];
        }
        Ok((logits, 1))
    }
}

fn cfg(max_batch: usize, workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(1),
            queue_cap: 10_000,
        },
        n_workers: workers,
        in_dim: 2,
    }
}

#[test]
fn failed_batches_drop_cleanly_and_service_continues() {
    let counter = Arc::new(AtomicUsize::new(0));
    let c = {
        let counter = Arc::clone(&counter);
        Coordinator::start(cfg(1, 1), move |_| {
            Box::new(FlakyBackend { counter: Arc::clone(&counter), fail_every: 3 })
        })
    };
    // max_batch = 1 ⇒ one batch per request ⇒ every 3rd fails.
    let rxs: Vec<_> = (0..30).map(|i| c.submit(vec![i as f32, 0.0]).unwrap()).collect();
    c.flush();
    let outcomes: Vec<bool> = rxs.into_iter().map(|rx| rx.recv().is_ok()).collect();
    let ok = outcomes.iter().filter(|&&b| b).count();
    let failed = outcomes.len() - ok;
    assert_eq!(failed, 10, "every third batch fails: {outcomes:?}");
    assert_eq!(ok, 20);
    // The service survived all failures; shutdown still works.
    let m = c.shutdown();
    assert_eq!(m.completed(), 20);
}

#[test]
fn saturation_recovers_after_burst() {
    let c = Coordinator::start(cfg(64, 2), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    // Burst far above the queue cap is impossible here (cap 10k); send a
    // large burst, then verify subsequent sequential traffic is healthy.
    let burst: Vec<_> = (0..5000).map(|_| c.submit(vec![0.0, 0.0]).unwrap()).collect();
    c.flush();
    for rx in burst {
        let _ = rx.recv();
    }
    for i in 0..20 {
        let r = c.infer(vec![i as f32, 0.0]).expect("post-burst request");
        assert_eq!(r.logits[0], i as f32);
    }
    c.shutdown();
}

#[test]
fn submit_after_shutdown_errors() {
    let c = Coordinator::start(cfg(4, 1), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    let _ = c.infer(vec![1.0, 2.0]).unwrap();
    // Move out of c via shutdown; a clone of the sender is not exposed —
    // the type system prevents use-after-shutdown. What we *can* check:
    // shutdown drains and returns sane metrics even with traffic racing.
    let m = c.shutdown();
    assert!(m.completed() >= 1);
}

#[test]
fn interleaved_shapes_are_isolated_per_request() {
    // Two clients with different payload magnitudes sharing batches must
    // each get their own logits back.
    let c = Coordinator::start(cfg(8, 2), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    let rxs: Vec<_> = (0..200)
        .map(|i| (i, c.submit(vec![i as f32 * 10.0, 0.0]).unwrap()))
        .collect();
    c.flush();
    for (i, rx) in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits[0], i as f32 * 10.0, "request {i} got someone else's result");
    }
    c.shutdown();
}

#[test]
fn zero_feature_vectors_are_valid() {
    let c = Coordinator::start(cfg(4, 1), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    let r = c.infer(vec![0.0, 0.0]).unwrap();
    assert_eq!(r.logits, vec![0.0, 0.0]);
    c.shutdown();
}
