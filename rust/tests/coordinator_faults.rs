//! Failure-injection tests for the serving coordinator: flaky backends,
//! panicking-workload shapes, saturation, and shutdown races — plus the
//! same scenarios replayed on the deterministic [`ServingRuntime`]
//! through the seeded fault injector, so both runtimes share one fault
//! vocabulary (`versal_gemm::fault`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;
use versal_gemm::coordinator::{
    Backend, BatcherConfig, Coordinator, CoordinatorConfig, EchoBackend, ServingConfig,
    ServingRuntime,
};
use versal_gemm::fault::{flaky_fails, FaultEvent, FaultInjector, FaultKind, FaultPlan, RetryPolicy};
use versal_gemm::gemm::Precision;

/// Backend that errors on every Nth batch. The decision delegates to
/// [`flaky_fails`] — the same schedule [`FaultKind::Flaky`] uses inside
/// the cycle-domain injector — so the threaded and deterministic
/// runtimes cannot drift apart on what "every 3rd batch fails" means.
struct FlakyBackend {
    counter: Arc<AtomicUsize>,
    fail_every: usize,
}

impl Backend for FlakyBackend {
    fn in_dim(&self) -> usize {
        2
    }
    fn n_classes(&self) -> usize {
        2
    }
    fn infer_batch(&mut self, batch: usize, x: &[f32]) -> anyhow::Result<(Vec<f32>, u64)> {
        let n = self.counter.fetch_add(1, Ordering::SeqCst) + 1;
        if flaky_fails(n as u64, self.fail_every as u64) {
            anyhow::bail!("injected failure on batch {n}");
        }
        let mut logits = vec![0.0f32; batch * 2];
        for i in 0..batch {
            logits[i * 2] = x[i * 2];
        }
        Ok((logits, 1))
    }
}

fn cfg(max_batch: usize, workers: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(1),
            queue_cap: 10_000,
        },
        n_workers: workers,
        in_dim: 2,
    }
}

#[test]
fn failed_batches_drop_cleanly_and_service_continues() {
    let counter = Arc::new(AtomicUsize::new(0));
    let c = {
        let counter = Arc::clone(&counter);
        Coordinator::start(cfg(1, 1), move |_| {
            Box::new(FlakyBackend { counter: Arc::clone(&counter), fail_every: 3 })
        })
    };
    // max_batch = 1 ⇒ one batch per request ⇒ every 3rd fails.
    let rxs: Vec<_> = (0..30).map(|i| c.submit(vec![i as f32, 0.0]).unwrap()).collect();
    c.flush();
    let outcomes: Vec<bool> = rxs.into_iter().map(|rx| rx.recv().is_ok()).collect();
    let ok = outcomes.iter().filter(|&&b| b).count();
    let failed = outcomes.len() - ok;
    assert_eq!(failed, 10, "every third batch fails: {outcomes:?}");
    assert_eq!(ok, 20);
    // The service survived all failures; shutdown still works.
    let m = c.shutdown();
    assert_eq!(m.completed(), 20);
}

#[test]
fn saturation_recovers_after_burst() {
    let c = Coordinator::start(cfg(64, 2), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    // Burst far above the queue cap is impossible here (cap 10k); send a
    // large burst, then verify subsequent sequential traffic is healthy.
    let burst: Vec<_> = (0..5000).map(|_| c.submit(vec![0.0, 0.0]).unwrap()).collect();
    c.flush();
    for rx in burst {
        let _ = rx.recv();
    }
    for i in 0..20 {
        let r = c.infer(vec![i as f32, 0.0]).expect("post-burst request");
        assert_eq!(r.logits[0], i as f32);
    }
    c.shutdown();
}

#[test]
fn submit_after_shutdown_errors() {
    let c = Coordinator::start(cfg(4, 1), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    let _ = c.infer(vec![1.0, 2.0]).unwrap();
    // Move out of c via shutdown; a clone of the sender is not exposed —
    // the type system prevents use-after-shutdown. What we *can* check:
    // shutdown drains and returns sane metrics even with traffic racing.
    let m = c.shutdown();
    assert!(m.completed() >= 1);
}

#[test]
fn interleaved_shapes_are_isolated_per_request() {
    // Two clients with different payload magnitudes sharing batches must
    // each get their own logits back.
    let c = Coordinator::start(cfg(8, 2), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    let rxs: Vec<_> = (0..200)
        .map(|i| (i, c.submit(vec![i as f32 * 10.0, 0.0]).unwrap()))
        .collect();
    c.flush();
    for (i, rx) in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits[0], i as f32 * 10.0, "request {i} got someone else's result");
    }
    c.shutdown();
}

#[test]
fn zero_feature_vectors_are_valid() {
    let c = Coordinator::start(cfg(4, 1), |_| {
        Box::new(versal_gemm::coordinator::EchoBackend { in_dim: 2, n_classes: 2 })
    });
    let r = c.infer(vec![0.0, 0.0]).unwrap();
    assert_eq!(r.logits, vec![0.0, 0.0]);
    c.shutdown();
}

// ---------------------------------------------------------------------
// The same fault scenarios, replayed on the deterministic cycle-domain
// runtime through the seeded injector. One fault vocabulary, two
// runtimes: `FaultKind::Flaky { every }` is the injector spelling of
// the `FlakyBackend` above (both delegate to `flaky_fails`).
// ---------------------------------------------------------------------

fn runtime_cfg(max_batch: usize, queue_cap: usize) -> ServingConfig {
    ServingConfig {
        max_batch,
        max_wait_us: 200,
        queue_cap,
        default_slo_us: 50_000,
        cache_budget_bytes: 1 << 20,
        plan_cache_budget_bytes: 1 << 20,
        pipeline_devices: 2,
        max_backlog_us: u64::MAX,
    }
}

fn flaky_runtime(every: u32, policy: RetryPolicy, max_batch: usize) -> ServingRuntime<EchoBackend> {
    let plan =
        FaultPlan::new(vec![FaultEvent { at_us: 0, kind: FaultKind::Flaky { every } }]);
    ServingRuntime::new(EchoBackend { in_dim: 2, n_classes: 2 }, runtime_cfg(max_batch, 64))
        .with_faults(FaultInjector::new(plan).with_policy(policy))
}

/// Port of `failed_batches_drop_cleanly_and_service_continues`: with
/// retries disabled and one request per batch, every 3rd batch fails —
/// the exact 20/10 split of the threaded coordinator — and the service
/// keeps running through all ten failures.
#[test]
fn runtime_failed_batches_drop_cleanly_and_service_continues() {
    let policy = RetryPolicy { max_retries: 0, backoff_us: 100, tenant_retry_budget: 1_024 };
    let mut rt = flaky_runtime(3, policy, 1);
    for i in 0..30u64 {
        rt.submit(vec![i as f32, 0.0], Precision::U8, i * 300).unwrap();
        rt.tick(i * 300);
    }
    rt.drain(30 * 300);
    let r = rt.report();
    assert_eq!(r.failed, 10, "every third batch fails, exactly as in the threaded port");
    assert_eq!(r.completed, 20);
    let f = r.faults.expect("injector attached");
    assert_eq!(f.retries, 0, "max_retries = 0 is the legacy drop-cleanly behaviour");
    assert_eq!(f.retry_exhausted, 10);
}

/// Port of `saturation_recovers_after_burst`: a burst far beyond the
/// queue cap with a transient fault in the middle sheds the overflow,
/// then subsequent sequential traffic is healthy — and unlike the
/// threaded runtime, the ledger proves nothing vanished.
#[test]
fn runtime_saturation_recovers_after_faulty_burst() {
    let plan = FaultPlan::new(vec![FaultEvent {
        at_us: 0,
        kind: FaultKind::Transient { count: 1 },
    }]);
    let mut rt =
        ServingRuntime::new(EchoBackend { in_dim: 2, n_classes: 2 }, runtime_cfg(4, 16))
            .with_faults(FaultInjector::new(plan));
    // Burst: 100 requests in one instant against a 16-deep queue.
    for i in 0..100u64 {
        let _ = rt.submit(vec![i as f32, 0.0], Precision::U8, 0);
    }
    rt.tick(0);
    rt.drain(1_000);
    let burst_report = rt.report();
    assert!(burst_report.completed > 0, "the queue's worth of work completes");
    // Post-burst sequential traffic is healthy: every request completes.
    let before = rt.report().completed;
    for i in 0..20u64 {
        let now = 10_000 + i * 500;
        rt.submit(vec![i as f32, 0.0], Precision::U8, now).unwrap();
        rt.tick(now);
    }
    rt.drain(30_000);
    let r = rt.report();
    assert_eq!(r.completed, before + 20, "post-burst traffic must be fault-free");
    let submitted: u64 = r.tenants.iter().map(|t| t.submitted).sum();
    assert_eq!(
        submitted,
        r.completed + r.failed + r.expired + r.shed + r.rejected,
        "burst + fault + recovery must conserve the ledger"
    );
}

/// Port of `interleaved_shapes_are_isolated_per_request`, hardened with
/// retries: even when every 2nd batch fails and its requests re-enter
/// forming (re-batched with *different* neighbours), each completed
/// request still gets its own logits back.
#[test]
fn runtime_retries_preserve_per_request_isolation() {
    let policy = RetryPolicy { max_retries: 3, backoff_us: 100, tenant_retry_budget: 1_024 };
    let mut rt = flaky_runtime(2, policy, 8);
    let mut expected = std::collections::HashMap::new();
    let mut outcomes = Vec::new();
    for i in 0..200u64 {
        let now = i * 50;
        let id = rt.submit(vec![i as f32 * 10.0, 0.0], Precision::U8, now).unwrap();
        expected.insert(id, i as f32 * 10.0);
        outcomes.extend(rt.tick(now));
    }
    outcomes.extend(rt.drain(200 * 50 + 1_000));
    assert!(!outcomes.is_empty(), "flaky-every-2nd must still complete work via retries");
    for o in &outcomes {
        let want = expected[&o.id];
        assert_eq!(
            o.logits[0], want,
            "request {:?} got someone else's result after a retry",
            o.id
        );
    }
    let r = rt.report();
    let f = r.faults.expect("injector attached");
    assert!(f.retries > 0, "the flaky schedule must have forced re-batching");
    assert_eq!(r.completed, outcomes.len() as u64);
}
