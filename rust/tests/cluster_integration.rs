//! Cluster-layer integration tests: the acceptance criteria of the
//! multi-device sharded GEMM, end to end.
//!
//! - bit-exactness of 2- and 4-device sharded GEMM against the
//!   single-device `ParallelGemm` (and the naive oracle) on non-square
//!   shapes, homogeneous and heterogeneous pools, with and without SUMMA
//!   k-chunking;
//! - device-level strong scaling: aggregate MACs/cycle rises 1 → 4
//!   devices with per-device efficiency ≥ 70% on the Table-2 problem;
//! - tensor-parallel serving through the coordinator: cluster-backed
//!   workers serve the MLP (bit-exact at equal batch composition —
//!   pinned by the worker unit test — and prediction-stable here).

use std::time::Duration;
use versal_gemm::arch::vc1902;
use versal_gemm::cluster::{
    Cluster, ClusterGemm, ClusterGemmConfig, DeviceSpec, FabricSpec, GridPlacement, Topology,
};
use versal_gemm::coordinator::BatcherConfig;
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::baseline::naive_gemm;
use versal_gemm::gemm::{Ccp, GemmConfig, MatI32, MatU8, ParallelGemm};
use versal_gemm::report;
use versal_gemm::util::Pcg32;

/// Three non-square shapes (m, k, n), none a multiple of MR/NR/kc.
const SHAPES: [(usize, usize, usize); 3] = [(40, 64, 48), (33, 57, 29), (12, 160, 24)];

fn small_cfg() -> ClusterGemmConfig {
    ClusterGemmConfig::with_ccp(Ccp { mc: 16, nc: 16, kc: 32 })
}

#[test]
fn sharded_gemm_bit_exact_on_2_and_4_devices() {
    for devices in [2usize, 4] {
        let cluster = Cluster::vc1902_pool(devices, 3).unwrap();
        let engine = ClusterGemm::new(&cluster);
        for &(m, k, n) in &SHAPES {
            let mut rng = Pcg32::new((devices * m * k * n) as u64);
            let a = MatU8::random(m, k, &mut rng);
            let b = MatU8::random(k, n, &mut rng);

            // Single-device reference (itself exact vs naive).
            let arch = vc1902();
            let single = ParallelGemm::new(&arch);
            let scfg = GemmConfig {
                ccp: Ccp { mc: 16, nc: 16, kc: 32 },
                tiles: 3,
                count_packing: false,
                steady_stream: true,
            };
            let mut want = MatI32::zeros(m, n);
            single.run(&scfg, &a, &b, &mut want).unwrap();
            let mut oracle = MatI32::zeros(m, n);
            naive_gemm(&a, &b, &mut oracle);
            assert_eq!(want.max_abs_diff(&oracle), 0);

            let mut c = MatI32::zeros(m, n);
            let (bd, stats) = engine.run_auto(&small_cfg(), &a, &b, &mut c).unwrap();
            assert_eq!(
                c.max_abs_diff(&want),
                0,
                "{devices}-device shard of ({m},{k},{n}) must equal single-device"
            );
            assert!(bd.total >= bd.compute);
            assert_eq!(stats.len(), devices);
            let total_macs: u64 = stats.iter().map(|s| s.macs).sum();
            assert!(total_macs > 0, "devices did the MACs");
        }
    }
}

#[test]
fn summa_chunked_and_explicit_grids_stay_exact() {
    let cluster = Cluster::vc1902_pool(4, 2).unwrap();
    let engine = ClusterGemm::new(&cluster);
    let (m, k, n) = (37, 96, 41);
    let mut rng = Pcg32::new(0x5117);
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let mut want = MatI32::zeros(m, n);
    naive_gemm(&a, &b, &mut want);
    for (rows, cols) in [(2, 2), (4, 1), (1, 4)] {
        for kb in [0usize, 32, 50] {
            let placement = GridPlacement::grid(&cluster, rows, cols, m, n).unwrap();
            let mut cfg = small_cfg();
            cfg.kb = kb;
            let mut c = MatI32::zeros(m, n);
            engine.run(&cfg, &placement, &a, &b, &mut c).unwrap();
            assert_eq!(
                c.max_abs_diff(&want),
                0,
                "grid {rows}x{cols}, kb={kb} must stay exact"
            );
        }
    }
}

#[test]
fn heterogeneous_pool_is_exact_and_balances_by_tiles() {
    let cluster = Cluster {
        devices: vec![
            DeviceSpec { arch: vc1902(), tiles: 6 },
            DeviceSpec { arch: vc1902(), tiles: 2 },
        ],
        topology: Topology::FullyConnected(2),
        fabric: FabricSpec::cxl_like(),
    };
    cluster.validate().unwrap();
    let engine = ClusterGemm::new(&cluster);
    let (m, k, n) = (64, 48, 40);
    let mut rng = Pcg32::new(0x4E7);
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let mut want = MatI32::zeros(m, n);
    naive_gemm(&a, &b, &mut want);
    let placement = GridPlacement::grid(&cluster, 2, 1, m, n).unwrap();
    assert_eq!(placement.row_bands, vec![48, 16], "3:1 tiles → 3:1 rows");
    let mut c = MatI32::zeros(m, n);
    let (_, stats) = engine.run(&small_cfg(), &placement, &a, &b, &mut c).unwrap();
    assert_eq!(c.max_abs_diff(&want), 0);
    assert!(
        stats[0].macs > 2 * stats[1].macs,
        "the 6-tile device does ~3x the work: {} vs {}",
        stats[0].macs,
        stats[1].macs
    );
}

#[test]
fn cluster_strong_scaling_acceptance_on_table2_problem() {
    // Schedule-only (pure arithmetic) so this stays cheap in debug CI.
    let rows =
        report::cluster_scaling_rows(&vc1902(), 8, &[1, 2, 4], &FabricSpec::pcie_like())
            .unwrap();
    for w in rows.windows(2) {
        assert!(
            w[1].aggregate_macs_per_cycle > w[0].aggregate_macs_per_cycle,
            "aggregate MACs/cycle must rise: {:?} → {:?}",
            (w[0].devices, w[0].aggregate_macs_per_cycle),
            (w[1].devices, w[1].aggregate_macs_per_cycle)
        );
    }
    for r in &rows {
        assert!(
            r.per_device_efficiency >= 0.70,
            "devices={}: per-device efficiency {:.3} < 0.70",
            r.devices,
            r.per_device_efficiency
        );
    }
}

#[test]
fn cluster_backed_coordinator_serves_the_mlp() {
    use versal_gemm::coordinator::{
        Backend, ClusterGemmBackend, Coordinator, CoordinatorConfig, RustGemmBackend,
    };
    let spec = MlpSpec { dims: vec![24, 16, 6] };
    let cfg = CoordinatorConfig {
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 1024,
        },
        n_workers: 2,
        in_dim: 24,
    };
    let spec2 = spec.clone();
    let coordinator = Coordinator::start(cfg, move |_| {
        let cluster = Cluster::vc1902_pool(2, 4).expect("pool");
        Box::new(ClusterGemmBackend::new(cluster, spec2.clone(), 31).expect("backend"))
    });

    // Oracle: a single-device backend over the same model seed. At equal
    // batch composition the two are bit-identical (pinned by the worker
    // unit test); through the dynamic batcher the compositions differ,
    // so compare the stable quantity — the predicted class.
    let mut oracle = RustGemmBackend::new(vc1902(), spec, 31, 4);
    let mut rng = Pcg32::new(0xBEEF);
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for _ in 0..12 {
        let x: Vec<f32> = (0..24).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
        let (logits, _) = oracle.infer_batch(1, &x).unwrap();
        let want = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        wants.push(want);
        rxs.push(coordinator.submit(x).unwrap());
    }
    coordinator.flush();
    let mut agree = 0;
    for (rx, want) in rxs.into_iter().zip(wants) {
        let resp = rx.recv().expect("served");
        assert!(resp.simulated_cycles > 0, "cluster cycles attached");
        assert_eq!(resp.logits.len(), 6);
        if resp.predicted_class == want {
            agree += 1;
        }
    }
    assert!(agree >= 10, "only {agree}/12 predictions agree with the oracle");
    let metrics = coordinator.shutdown();
    assert_eq!(metrics.completed(), 12);
}
