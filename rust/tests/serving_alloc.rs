//! The pack arena's zero-allocation contract, pinned with a counting
//! global allocator.
//!
//! Two regimes are pinned:
//!
//! 1. **The warm plan walk allocates literally nothing.** Once a
//!    [`PackArena`]'s free lists hold a buffer per pack extent of a
//!    plan, replaying the walk — checkout, fill, recycle for every
//!    `Pack`/`Release` step — must be **zero bytes** of heap traffic:
//!    the step stream is the O(1) [`PlanSpec::walk`] iterator and every
//!    pack buffer is served from recycled capacity.
//!
//! 2. **Warm serving ticks are allocation-flat.** A full serving tick
//!    cannot be literally zero-byte (each request carries an owned
//!    feature vector, quantisation materialises per-batch operands, and
//!    every outcome owns its logits), but in the steady state — plan
//!    cache hot, packed-B resident, arena free lists primed — a tick
//!    must allocate **exactly the same bytes as the previous tick**
//!    (nothing grows with uptime), strictly fewer than the cold tick,
//!    and the arena must serve every pack from recycled capacity
//!    (`fresh` counter flat, `recycled` still advancing).
//!
//! This file deliberately holds a single `#[test]`: the harness runs
//! tests of one binary concurrently, and a second test would race the
//! global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{RustGemmBackend, ServingConfig, ServingRuntime};
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::{pack_a_in, pack_b_in, Ccp, GemmConfig, Mat, Precision};
use versal_gemm::plan::{Buffer, PlanSpec, PlanStep};
use versal_gemm::runtime::PackArena;
use versal_gemm::util::Pcg32;

struct CountingAlloc;

static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocated_during(f: impl FnOnce() -> u64) -> (u64, u64) {
    let before = BYTES.load(Ordering::SeqCst);
    let out = f();
    (out, BYTES.load(Ordering::SeqCst) - before)
}

/// Replay a plan's pack schedule against the arena: checkout + fill on
/// every `Pack` step, recycle on the matching `Release`. Returns a
/// checksum of the packed bytes so the packs cannot be optimised away.
fn pack_walk(arena: &PackArena, spec: &PlanSpec, a: &Mat<u8>, b: &Mat<u8>) -> u64 {
    let mut ac = None;
    let mut bc = None;
    let mut sum = 0u64;
    for step in spec.walk() {
        match step {
            PlanStep::Pack(p) => match p.buffer {
                Buffer::Ac => {
                    let packed = pack_a_in(arena, a, p.row_off, p.col_off, p.rows, p.cols);
                    sum = sum.wrapping_add(packed.data.iter().map(|&x| x as u64).sum::<u64>());
                    ac = Some(packed);
                }
                Buffer::Bc => {
                    let packed = pack_b_in(arena, b, p.row_off, p.col_off, p.rows, p.cols);
                    sum = sum.wrapping_add(packed.data.iter().map(|&x| x as u64).sum::<u64>());
                    bc = Some(packed);
                }
            },
            PlanStep::Release(r) => match r.buffer {
                Buffer::Ac => {
                    if let Some(packed) = ac.take() {
                        arena.recycle(packed.data);
                    }
                }
                Buffer::Bc => {
                    if let Some(packed) = bc.take() {
                        arena.recycle(packed.data);
                    }
                }
            },
            PlanStep::Compute(_) => {}
        }
    }
    sum
}

/// One serving round: four same-precision requests fused and drained.
/// Returns a checksum of the logits so the batch cannot be optimised
/// away. The feature vectors are freshly allocated each round — that
/// traffic is identical round over round, so flatness still pins the
/// steady state.
fn serve_round(rt: &mut ServingRuntime<RustGemmBackend>, round: u64) -> u64 {
    let t = round * 10_000;
    for i in 0..4u64 {
        let features: Vec<f32> = (0..16).map(|j| ((round + i + j) as f32).sin()).collect();
        rt.submit(features, Precision::U8, t + i).expect("admission");
    }
    let outcomes = rt.drain(t + 4);
    assert_eq!(outcomes.len(), 4, "all four requests complete");
    outcomes
        .iter()
        .flat_map(|o| o.logits.iter())
        .fold(0u64, |acc, &x| acc.wrapping_add(x.to_bits() as u64))
}

#[test]
fn warm_pack_path_allocates_zero_bytes() {
    // --- Regime 1: the warm plan walk is literally zero-alloc ---------
    let arch = vc1902();
    let mut cfg = GemmConfig::paper_table2(2);
    cfg.ccp = Ccp { mc: 32, nc: 32, kc: 64 };
    let (m, n, k) = (96, 80, 128);
    let mut rng = Pcg32::new(0xA110C);
    let a = Mat::<u8>::random(m, k, &mut rng);
    let b = Mat::<u8>::random(k, n, &mut rng);
    let spec = PlanSpec::new(&arch, &cfg, m, n, k, Precision::U8, false).expect("feasible plan");
    let arena = PackArena::new();

    // Cold walk primes the free lists (and warms lazily-initialised
    // runtime state, as tuner_streaming.rs does before measuring).
    let cold_sum = pack_walk(&arena, &spec, &a, &b);
    let primed = arena.stats();
    assert!(primed.fresh > 0, "cold walk must have allocated pack buffers");

    let (warm_sum, warm_bytes) = allocated_during(|| pack_walk(&arena, &spec, &a, &b));
    assert_eq!(warm_sum, cold_sum, "warm walk packs the same bytes");
    assert_eq!(
        warm_bytes, 0,
        "warm plan walk must perform zero heap allocation, allocated {warm_bytes} B"
    );
    let warm = arena.stats();
    assert_eq!(warm.fresh, primed.fresh, "warm walk checked out no fresh buffer");
    assert!(warm.recycled > primed.recycled, "warm walk ran through the free lists");

    // --- Regime 2: warm serving ticks are allocation-flat -------------
    let spec = MlpSpec { dims: vec![16, 12, 4] };
    let backend = RustGemmBackend::new(vc1902(), spec, 42, 2);
    let arena = Arc::clone(backend.arena());
    let mut cfg = ServingConfig::default();
    cfg.max_batch = 4;
    let mut rt = ServingRuntime::new(backend, cfg);

    // Round 0 is the cold path: plan lowering, packed-B prepack, fresh
    // arena buffers. Rounds 1..=9 settle every amortised structure
    // (admission-queue capacity, latency-sample vectors — their doubling
    // growth must not fire inside the measured window).
    let (_, cold_bytes) = allocated_during(|| serve_round(&mut rt, 0));
    for round in 1..10 {
        serve_round(&mut rt, round);
    }

    let before = arena.stats();
    let (sum_a, bytes_a) = allocated_during(|| serve_round(&mut rt, 10));
    let (sum_b, bytes_b) = allocated_during(|| serve_round(&mut rt, 11));
    let after = arena.stats();

    assert!(sum_a > 0 && sum_b > 0, "rounds produced logits");
    assert_eq!(
        bytes_a, bytes_b,
        "warm ticks must be allocation-flat: {bytes_a} B then {bytes_b} B"
    );
    assert!(
        bytes_a < cold_bytes,
        "a warm tick ({bytes_a} B) must allocate strictly less than the cold tick \
         ({cold_bytes} B): plan cache hot, packed-B resident, arena primed"
    );
    assert_eq!(
        after.fresh, before.fresh,
        "warm ticks must check out no fresh arena buffer (fresh {} -> {})",
        before.fresh, after.fresh
    );
    assert!(
        after.recycled > before.recycled,
        "warm ticks must actually pack through the arena's free lists"
    );
}
