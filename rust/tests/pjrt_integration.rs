//! Integration tests across the three layers: the Rust GEMM engine, the
//! PJRT runtime, and the JAX/Pallas artifacts produced by `make artifacts`.
//!
//! These tests are skipped (with a loud message) when the artifacts are
//! missing so a clean checkout can still run `cargo test`; `make test`
//! always builds artifacts first.
//!
//! Environment-blocked: the whole file is gated behind the `pjrt` cargo
//! feature (the `xla` crate needs network + libxla, unavailable offline),
//! and each test additionally carries `#[ignore]` so even a `--features
//! pjrt` run must opt in with `--ignored` once artifacts exist.

#![cfg(feature = "pjrt")]

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::baseline::naive_gemm;
use versal_gemm::gemm::{GemmConfig, MatI32, MatU8, ParallelGemm};
use versal_gemm::runtime::{ArtifactId, ArtifactRegistry, Engine};
use versal_gemm::util::Pcg32;

fn engine_or_skip() -> Option<Engine> {
    let reg = ArtifactRegistry::default_location();
    if !reg.missing().is_empty() {
        eprintln!(
            "SKIP: artifacts missing at {} — run `make artifacts`",
            reg.root().display()
        );
        return None;
    }
    Some(Engine::new(reg).expect("PJRT CPU client"))
}

#[test]
#[ignore = "environment-blocked: needs the xla crate (network + libxla) and `make artifacts`"]
fn pallas_microkernel_artifact_matches_rust_engine_exactly() {
    let Some(mut eng) = engine_or_skip() else { return };
    let mut rng = Pcg32::new(0xA0);
    let a = MatU8::random(64, 64, &mut rng);
    let b = MatU8::random(64, 64, &mut rng);

    // Layer 1/2: the Pallas micro-kernel via PJRT.
    let from_pjrt = eng.gemm_u8(ArtifactId::GemmU8_64, &a, &b).expect("PJRT GEMM");

    // Layer 3: the Rust engine (parallel, 4 simulated tiles).
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut cfg = GemmConfig::paper_table2(4);
    cfg.ccp = versal_gemm::gemm::Ccp { mc: 32, nc: 32, kc: 64 };
    let mut from_rust = MatI32::zeros(64, 64);
    engine.run(&cfg, &a, &b, &mut from_rust).unwrap();

    // And the naive oracle.
    let mut from_naive = MatI32::zeros(64, 64);
    naive_gemm(&a, &b, &mut from_naive);

    assert_eq!(from_pjrt.max_abs_diff(&from_rust), 0, "PJRT vs Rust engine");
    assert_eq!(from_pjrt.max_abs_diff(&from_naive), 0, "PJRT vs naive");
}

#[test]
#[ignore = "environment-blocked: needs the xla crate (network + libxla) and `make artifacts`"]
fn paper_problem_artifact_matches_rust_engine() {
    let Some(mut eng) = engine_or_skip() else { return };
    let mut rng = Pcg32::new(0xA1);
    let a = MatU8::random(256, 2048, &mut rng);
    let b = MatU8::random(2048, 256, &mut rng);

    let from_pjrt = eng.gemm_u8(ArtifactId::GemmU8Paper, &a, &b).expect("PJRT GEMM");

    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let cfg = GemmConfig::paper_table2(8);
    let mut from_rust = MatI32::zeros(256, 256);
    let (cycles, _) = engine.run(&cfg, &a, &b, &mut from_rust).unwrap();

    assert_eq!(from_pjrt.max_abs_diff(&from_rust), 0, "paper-shape numerics");
    assert!(cycles.total > 0);
}

#[test]
#[ignore = "environment-blocked: needs the xla crate (network + libxla) and `make artifacts`"]
fn mlp_artifact_runs_and_is_deterministic() {
    let Some(mut eng) = engine_or_skip() else { return };
    let mut rng = Pcg32::new(0xA2);
    let x: Vec<f32> = (0..8 * 784).map(|_| rng.f64() as f32).collect();
    let y1 = eng.mlp_forward(8, &x).expect("MLP forward");
    let y2 = eng.mlp_forward(8, &x).expect("MLP forward");
    assert_eq!(y1.len(), 8 * 10);
    assert_eq!(y1, y2, "deterministic");
    assert!(y1.iter().all(|v| v.is_finite()), "finite logits");
    // Logits must not be all identical (the model computes something).
    let spread = y1.iter().fold((f32::MAX, f32::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    assert!(spread.1 - spread.0 > 1e-3, "logit spread {spread:?}");
}

#[test]
#[ignore = "environment-blocked: needs the xla crate (network + libxla) and `make artifacts`"]
fn gemm_artifact_rejects_nothing_but_shapes_hold() {
    // Contract check: the artifact registry's stems match what aot.py
    // wrote (i.e. make artifacts produced exactly these files).
    let reg = ArtifactRegistry::default_location();
    if reg.missing().is_empty() {
        for id in ArtifactId::ALL {
            assert!(reg.path(id).is_file());
            let text = std::fs::read_to_string(reg.path(id)).unwrap();
            assert!(text.contains("HloModule") || text.contains("ENTRY"), "{id:?} looks like HLO text");
        }
    }
}
