//! Integration tests of the continuous-batching serving runtime over
//! the real GEMM backend: batcher edge cases (empty tick, oversize
//! cache entries, mixed precisions, deadline expiry) and the cache's
//! bit-exactness contract, end to end.

use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    AdmitError, RustGemmBackend, ServingConfig, ServingRuntime,
};
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::Precision;
use versal_gemm::util::Pcg32;

fn small_runtime(cfg: ServingConfig) -> ServingRuntime<RustGemmBackend> {
    let spec = MlpSpec { dims: vec![16, 12, 4] };
    ServingRuntime::new(RustGemmBackend::new(vc1902(), spec, 99, 4), cfg)
}

fn features(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg32::new(seed);
    (0..n)
        .map(|_| (0..16).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect())
        .collect()
}

#[test]
fn empty_queue_tick_with_real_backend() {
    let mut rt = small_runtime(ServingConfig::default());
    assert!(rt.tick(0).is_empty());
    assert!(rt.drain(0).is_empty());
    let r = rt.report();
    assert_eq!(r.batches, 0);
    assert_eq!(r.cache.misses, 0, "no batch, no cache traffic");
}

#[test]
fn oversize_weights_served_transiently_without_wiping_cache() {
    // A budget below one layer's packed footprint: every batch misses,
    // nothing is ever resident, the uncacheable counter grows — but the
    // requests are still answered, and correctly.
    let mut rt = small_runtime(ServingConfig {
        max_batch: 4,
        cache_budget_bytes: 8, // smaller than any packed layer
        ..Default::default()
    });
    let fs = features(4, 1);
    for (i, f) in fs.iter().enumerate() {
        rt.submit(f.clone(), Precision::U8, i as u64).unwrap();
    }
    let out = rt.drain(10);
    assert_eq!(out.len(), 4, "oversize weights must not drop requests");
    let r = rt.report();
    assert_eq!(r.cache.bytes, 0, "nothing resident under a tiny budget");
    assert_eq!(r.cache.uncacheable, 2, "both layers refused: {:?}", r.cache);
    assert_eq!(r.cache.hits, 0);

    // And the logits equal a comfortably-cached runtime's on the same
    // fused batch — the transient path is the same numerics.
    let mut cached = small_runtime(ServingConfig {
        max_batch: 4,
        cache_budget_bytes: 64 << 20,
        ..Default::default()
    });
    for (i, f) in fs.iter().enumerate() {
        cached.submit(f.clone(), Precision::U8, i as u64).unwrap();
    }
    let want = cached.drain(10);
    for (a, b) in out.iter().zip(&want) {
        assert_eq!(a.logits, b.logits, "transient pack is bit-exact with cached");
    }
}

#[test]
fn mixed_precision_requests_do_not_coalesce_end_to_end() {
    let mut rt = small_runtime(ServingConfig { max_batch: 8, ..Default::default() });
    let fs = features(6, 2);
    let precisions = [
        Precision::U8,
        Precision::Bf16,
        Precision::U8,
        Precision::I16,
        Precision::Bf16,
        Precision::U8,
    ];
    for (i, (f, p)) in fs.iter().zip(precisions).enumerate() {
        rt.submit(f.clone(), p, i as u64).unwrap();
    }
    let out = rt.drain(100);
    assert_eq!(out.len(), 6);
    for o in &out {
        let expect = match o.precision {
            Precision::U8 => 3,
            Precision::Bf16 => 2,
            Precision::I16 => 1,
            Precision::I8 => unreachable!("no i8 requests in the trace"),
        };
        assert_eq!(
            o.batch_size, expect,
            "{} batch must contain exactly the same-precision requests",
            o.precision
        );
    }
    let r = rt.report();
    assert_eq!(r.batches, 3, "one fused batch per precision class");
    // Distinct (layer, precision) cache entries: 2 layers × 3 precisions.
    assert_eq!(r.cache.misses, 6);
}

#[test]
fn deadline_expired_requests_evicted_with_real_backend() {
    let mut rt = small_runtime(ServingConfig {
        max_batch: 8,
        max_wait_us: 10_000,
        default_slo_us: 100,
        ..Default::default()
    });
    let fs = features(3, 3);
    rt.submit(fs[0].clone(), Precision::U8, 0).unwrap(); // deadline 100
    rt.submit(fs[1].clone(), Precision::U8, 50).unwrap(); // deadline 150
    // Past both deadlines: both evicted, nothing served.
    let out = rt.tick(200);
    assert!(out.is_empty());
    let r = rt.report();
    assert_eq!(r.expired, 2);
    assert_eq!(r.completed, 0);
    // A fresh request after the purge is served normally.
    rt.submit(fs[2].clone(), Precision::U8, 300).unwrap();
    let out = rt.drain(300);
    assert_eq!(out.len(), 1);
    assert_eq!(rt.report().expired, 2, "no further expiries");
    // Submitting with an already-passed deadline is rejected at the door.
    assert_eq!(
        rt.submit_with_deadline(fs[2].clone(), Precision::U8, 400, 399),
        Err(AdmitError::DeadlinePassed)
    );
}

#[test]
fn warm_cache_replay_bit_exact_and_cheaper() {
    let mut rt = small_runtime(ServingConfig { max_batch: 4, ..Default::default() });
    let fs = features(4, 4);
    for f in &fs {
        rt.submit(f.clone(), Precision::I16, 0).unwrap();
    }
    let cold = rt.drain(0);
    let cold_pack = rt.report().pack_cycles;
    for f in &fs {
        rt.submit(f.clone(), Precision::I16, 1_000).unwrap();
    }
    let warm = rt.drain(1_000);
    let total_pack = rt.report().pack_cycles;
    assert_eq!(cold.len(), warm.len());
    for (a, b) in cold.iter().zip(&warm) {
        assert_eq!(a.logits, b.logits, "i16 cache hit bit-exact with cold pack");
    }
    assert!(
        total_pack - cold_pack < cold_pack,
        "warm wave packs strictly less than the cold wave: {} vs {}",
        total_pack - cold_pack,
        cold_pack
    );
    let r = rt.report();
    assert_eq!(r.cache.hits, 2, "both layers hit on the warm wave");
    // The plan cache amortised the lowering identically: one plan per
    // layer on the cold wave, pure hits on the warm replay.
    assert_eq!(r.plan_cache.lowered, 2, "plans lowered once, not per wave");
    assert_eq!(r.plan_cache.hits, 2, "warm wave reused both layer plans");
}

#[test]
fn plan_cache_off_is_bit_exact_and_same_cycles_as_on() {
    // The lowered-plan cache is a host-side optimisation: switching it
    // off (budget 0 ⇒ re-lower per batch, the pre-cache behaviour) must
    // change *nothing* in the simulated cycle domain or the logits —
    // only the lowering counters.
    let run = |plan_budget: u64| {
        let mut rt = small_runtime(ServingConfig {
            max_batch: 4,
            plan_cache_budget_bytes: plan_budget,
            ..Default::default()
        });
        let fs = features(4, 5);
        for now in [0u64, 1_000] {
            for f in &fs {
                rt.submit(f.clone(), Precision::U8, now).unwrap();
            }
            rt.drain(now);
        }
        let logits: Vec<Vec<f32>> = {
            // Re-serve a third identical wave and collect its outcomes.
            for f in &fs {
                rt.submit(f.clone(), Precision::U8, 2_000).unwrap();
            }
            rt.drain(2_000).into_iter().map(|o| o.logits).collect()
        };
        (logits, rt.report())
    };
    let (on_logits, on) = run(8 << 20);
    let (off_logits, off) = run(0);
    assert_eq!(on_logits, off_logits, "plan cache must not change numerics");
    assert_eq!(on.pack_cycles, off.pack_cycles, "same simulated pack charges");
    assert_eq!(on.pipelined_cycles, off.pipelined_cycles, "same makespan");
    // Three waves × 2 layers: the cache lowers once per layer, the
    // re-lower-per-batch baseline lowers on every wave.
    assert_eq!(on.plan_cache.lowered, 2);
    assert_eq!(off.plan_cache.lowered, 6);
    assert_eq!(off.plan_cache.hits, 0);
    assert!(on.plan_cache.hits >= 4, "warm waves hit the resident plans");
}
