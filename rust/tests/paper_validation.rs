//! Paper-validation suite: every quantitative claim of the paper that
//! this reproduction targets, pinned in one place. This is the
//! machine-checkable version of EXPERIMENTS.md.

use versal_gemm::arch::{vc1902, MemLevel};
use versal_gemm::gemm::ablation::{evaluate, LoopChoice};
use versal_gemm::gemm::{Ccp, GemmConfig, ParallelGemm};
use versal_gemm::sim::{AieTileModel, Gmio, KernelMode, Multicast, Stream};

const PROBLEM: (usize, usize, usize) = (256, 256, 2048);

// ---------------------------------------------------------------- Table 1
#[test]
fn table1_memory_hierarchy() {
    let a = vc1902();
    // Capacities as printed in Table 1.
    assert_eq!(a.mem_capacity(MemLevel::VectorRegisters), 2 * 1024); // 2 KB
    assert_eq!(a.mem_capacity(MemLevel::LocalMemory), 32 * 1024); // 32 KB
    assert!((a.mem_capacity(MemLevel::UltraRam) as f64 / 1e6 - 17.06).abs() < 0.1); // 16.27 MiB
    assert!((a.mem_capacity(MemLevel::BlockRam) as f64 / 1e6 - 4.46).abs() < 0.1); // 4.25 MiB
    assert_eq!(a.mem_capacity(MemLevel::Ddr), 2 << 30); // 2 GB
    // Operand mapping.
    assert_eq!(MemLevel::VectorRegisters.operands(), "Cr");
    assert_eq!(MemLevel::LocalMemory.operands(), "Br");
    assert_eq!(MemLevel::UltraRam.operands(), "Ac, Ar");
    assert_eq!(MemLevel::BlockRam.operands(), "Bc");
    assert_eq!(MemLevel::Ddr.operands(), "A, B, C");
}

// ------------------------------------------------------------------- §3
#[test]
fn section3_platform_constants() {
    let a = vc1902();
    assert_eq!(a.aie.n_tiles, 400);
    // "up to 128 (8-bit integer) GigaMAC ... at their peak" per tile at
    // 1 GHz ⇔ 128 MACs/cycle.
    assert_eq!(a.peak_macs_per_cycle(), 128.0);
}

// ------------------------------------------------------------------ §4.2
#[test]
fn section42_microkernel_geometry() {
    use versal_gemm::gemm::{MR, NR};
    assert_eq!((MR, NR), (8, 8));
    // mac16: 128 MACs/cycle; 8 calls per unrolled iteration computing
    // 1024 MACs over 256 fetched bytes.
    let a = vc1902();
    let m = AieTileModel::new(&a);
    assert_eq!(AieTileModel::UNROLL, 16);
    assert_eq!(AieTileModel::MACS16_PER_ITER, 8);
    assert_eq!(m.macs(8, 8, 2048), 131_072); // §5.2
    assert_eq!(m.macs_per_ar_byte(), 8.0); // §5.3
}

// ------------------------------------------------------------------ §4.3
#[test]
fn section43_ccp_derivation() {
    let a = vc1902();
    let ccp = Ccp::derive(&a, 1);
    // kc upper limit ~3750 "sparing about 2.5 KB".
    assert!((ccp.kc as f64 - 3750.0).abs() / 3750.0 < 0.01, "kc {}", ccp.kc);
    // mc "about 4,500"; nc "derived as 1,200".
    assert!((ccp.mc as f64 - 4500.0).abs() / 4500.0 < 0.05, "mc {}", ccp.mc);
    assert!((ccp.nc as f64 - 1200.0).abs() / 1200.0 < 0.05, "nc {}", ccp.nc);
}

// ------------------------------------------------------------------ §4.4
#[test]
fn section44_loop_choice() {
    let a = vc1902();
    let cfg = GemmConfig::paper_table2(16);
    // L2/L6 race; L4 beats L1/L3/L5 on this memory organisation.
    assert!(evaluate(&a, &cfg, LoopChoice::L2).is_err());
    assert!(evaluate(&a, &cfg, LoopChoice::L6).is_err());
    let l4 = evaluate(&a, &cfg, LoopChoice::L4).unwrap().total_cycles;
    for other in [LoopChoice::L1, LoopChoice::L3, LoopChoice::L5] {
        assert!(l4 < evaluate(&a, &cfg, other).unwrap().total_cycles);
    }
}

// ------------------------------------------------------------------ §4.5
#[test]
fn section45_gmio_footprint_and_rates() {
    let a = vc1902();
    let g = Gmio::new(&a);
    // "transmitting 10 KB ... necessitates an additional 20 KB".
    assert_eq!(g.local_footprint_bytes(10 * 1024) - 10 * 1024, 20 * 1024);
    // Streaming frees the buffers ⇒ larger kc ⇒ §4.5's 30 → 37.4
    // MACs/cycle improvement; here: the structural inequality.
    let m = AieTileModel::new(&a);
    let small = m.kernel_cycles(1024, KernelMode::Baseline, false).total + g.window_sync_cycles();
    let large = m.kernel_cycles(3744, KernelMode::Baseline, true).total;
    let rate_small = (8 * 8 * 1024) as f64 / small as f64;
    let rate_large = (8 * 8 * 3744) as f64 / large as f64;
    assert!(rate_large > rate_small * 1.15, "{rate_large} vs {rate_small}");
}

#[test]
fn section45_reuse_factors() {
    // "the same buffer Bc is accessed once per iteration of loop L3 (m/mc
    // times); Ac once per iteration of L4 (nc/nr); Br once per L5 (kc)".
    let (mc, nc, _kc) = (256, 256, 2048);
    let (m, _n, _k) = (1024, 1024, 4096);
    assert_eq!(m / mc, 4); // Bc reuse
    assert_eq!(nc / 8, 32); // Ac reuse
}

// ------------------------------------------------------------------ §5.1
#[test]
fn section51_transfer_costs() {
    let a = vc1902();
    let s = Stream::new(&a);
    // Br copy: constant 3280 cycles, independent of the tile count.
    assert_eq!(s.br_copy_cycles(2048 * 8), 3280);
    // Ar vector ≈ 19 cycles, independent of tile count (multicast).
    let m1 = Multicast::new(&a, 1).unwrap();
    let m32 = Multicast::new(&a, 32).unwrap();
    assert_eq!(m1.v64_cycles(), 19);
    assert_eq!(m1.v64_cycles(), m32.v64_cycles());
    // Copy-Cr column: 40 cycles at one tile, growing to ≈282 at 32.
    let g = Gmio::new(&a);
    assert_eq!(g.cr_roundtrip_cycles(1), 40);
    let c32 = g.cr_roundtrip_cycles(32);
    assert!((c32 as f64 - 282.0).abs() / 282.0 < 0.05, "{c32}");
}

// ------------------------------------------------------------------ §5.2
#[test]
fn section52_arithmetic_cost() {
    let a = vc1902();
    let m = AieTileModel::new(&a);
    // kc/16 iterations × 8 mac16 × 128 MACs = 131072 MACs; 1024 cycles of
    // pure arithmetic; linear scaling once data is resident.
    assert_eq!(m.arith_cycles_theoretical(2048), 1024);
    assert_eq!(m.arith_cycles(2048), 1042); // with measured loop overhead
}

// ---------------------------------------------------------------- Table 3
#[test]
fn table3_all_rows() {
    let a = vc1902();
    let m = AieTileModel::new(&a);
    let rows = [
        (KernelMode::ReadArOnly, 4106u64, 4864u64),
        (KernelMode::MacOnly, 1042, 1024),
        (KernelMode::Baseline, 4110, 5888),
    ];
    for (mode, measured, theory) in rows {
        assert_eq!(m.kernel_cycles(2048, mode, false).total, measured, "{mode:?}");
        assert_eq!(m.kernel_cycles_theoretical(2048, mode), theory, "{mode:?}");
    }
}

// ---------------------------------------------------------------- Table 2
#[test]
fn table2_full_reproduction() {
    let a = vc1902();
    let g = ParallelGemm::new(&a);
    let paper: [(usize, u64, f64, f64); 6] = [
        (1, 40, 3694.1e3, 31.5),
        (2, 58, 1916.0e3, 31.4),
        (4, 63, 958.1e3, 31.3),
        (8, 84, 498.9e3, 31.2),
        (16, 157, 275.3e3, 30.7),
        (32, 282, 162.9e3, 29.8),
    ];
    for (tiles, cr, total, perf) in paper {
        let row = g.table2_row(tiles);
        // Copy Cr within 25% (the paper's own small-N values are noisy),
        // exact at the endpoints.
        let cr_err = (row.copy_cr_cycles as f64 - cr as f64).abs() / cr as f64;
        assert!(cr_err < 0.25, "tiles={tiles} cr {} vs {cr}", row.copy_cr_cycles);
        // Arithmetic column: constant 4110.
        assert_eq!(row.arithmetic_cycles, 4110);
        // Total within 6%.
        let terr = (row.total_cycles as f64 - total).abs() / total;
        assert!(terr < 0.06, "tiles={tiles} total {} vs {total}", row.total_cycles);
        // Perf/tile near the printed precision (±0.15; the N=2 row
        // inherits the arbiter's 48-vs-58-cycle Cr residual).
        assert!((row.perf_per_tile - perf).abs() <= 0.15, "tiles={tiles} perf {}", row.perf_per_tile);
    }
}

// ------------------------------------------------------------------ §5.3
#[test]
fn section53_overlap_and_memory_bound() {
    let a = vc1902();
    let m = AieTileModel::new(&a);
    let read = m.kernel_cycles(2048, KernelMode::ReadArOnly, false).total;
    let mac = m.kernel_cycles(2048, KernelMode::MacOnly, false).total;
    let base = m.kernel_cycles(2048, KernelMode::Baseline, false).total;
    // "the cost should then be 4106 + 1042 = 5148 ... the actual
    // experiments show the cost matches that of reading Ar: 4110".
    assert_eq!(read + mac, 5148);
    assert!(base < read + mac);
    assert!(base - read <= a.aie.pipeline_drain_cycles);
    // Naive estimate below measured (the overlap's win) and both far
    // below peak (communication-bound).
    let naive = m.naive_macs_per_cycle_estimate();
    let measured = 131072.0 / (base + 40) as f64;
    assert!(naive < measured);
    assert!(measured < a.peak_macs_per_cycle() / 3.0);
}

// ------------------------------------------------------------------ §5.4
#[test]
fn section54_strong_scaling_efficiency() {
    let a = vc1902();
    let g = ParallelGemm::new(&a);
    let r1 = g.table2_row(1);
    let r32 = g.table2_row(32);
    let drop = 1.0 - r32.perf_per_tile / r1.perf_per_tile;
    // Paper: 5.7% degradation from 1 → 32 tiles.
    assert!((drop - 0.057).abs() < 0.01, "degradation {drop}");
}

// ------------------------------------------- whole-problem sanity check
#[test]
fn problem_constants() {
    let (m, n, k) = PROBLEM;
    assert_eq!(m * n * k, 134_217_728); // total MACs of the fixed problem
}
