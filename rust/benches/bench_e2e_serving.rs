//! Bench: **end-to-end serving** — throughput/latency of the coordinator
//! on the quantised-MLP workload across batch sizes and worker counts
//! (the deployment-side complement to Table 2's kernel scaling).
//!
//! Uses the pure-Rust backend so the bench needs no artifacts and
//! measures the coordinator + GEMM engine, not XLA compile time.
//!
//! ```bash
//! cargo bench --bench bench_e2e_serving
//! ```

use std::time::{Duration, Instant};
use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RustGemmBackend,
};
use versal_gemm::dl::MlpSpec;
use versal_gemm::util::tabulate::Table;
use versal_gemm::util::Pcg32;

fn run_once(workers: usize, max_batch: usize, requests: usize) -> (f64, f64, f64, f64) {
    let spec = MlpSpec { dims: vec![64, 48, 10] }; // small model: bench the fabric
    let in_dim = spec.dims[0];
    let c = Coordinator::start(
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch,
                max_wait: Duration::from_micros(500),
                queue_cap: 65536,
            },
            n_workers: workers,
            in_dim,
        },
        move |_| Box::new(RustGemmBackend::new(vc1902(), MlpSpec { dims: vec![64, 48, 10] }, 3, 4)),
    );
    let mut rng = Pcg32::new(1);
    // Warmup.
    for _ in 0..8 {
        let _ = c.infer((0..in_dim).map(|_| 0.1f32).collect());
    }
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|_| c.submit((0..in_dim).map(|_| rng.f64() as f32).collect()).unwrap())
        .collect();
    c.flush();
    let mut latencies: Vec<f64> = Vec::with_capacity(requests);
    for rx in rxs {
        let resp = rx.recv().expect("response");
        latencies.push(resp.latency.as_secs_f64() * 1e6);
    }
    let wall = t0.elapsed().as_secs_f64();
    c.shutdown();
    latencies.sort_by(|x, y| x.partial_cmp(y).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p99 = latencies[latencies.len() * 99 / 100];
    (requests as f64 / wall, p50, p99, wall * 1e3)
}

fn main() {
    let fast = std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let requests = if fast { 512 } else { 4096 };

    println!("=== end-to-end serving: coordinator + Rust GEMM backend ===");
    println!("(quantised MLP 64-48-10, {requests} closed-loop requests)\n");
    let mut t = Table::new(&["workers", "max batch", "req/s", "p50 µs", "p99 µs", "wall ms"]);
    for &workers in &[1usize, 2, 4] {
        for &batch in &[1usize, 8, 32] {
            let (rps, p50, p99, wall) = run_once(workers, batch, requests);
            t.row(&[
                workers.to_string(),
                batch.to_string(),
                format!("{rps:.0}"),
                format!("{p50:.0}"),
                format!("{p99:.0}"),
                format!("{wall:.0}"),
            ]);
        }
    }
    println!("{}", t.to_text());
    println!(
        "batching amortises the per-batch GEMM setup exactly like larger kc \
         amortises the Cr transfer (§4.2) — throughput rises with max batch, \
         p99 pays the grouping delay."
    );
}
