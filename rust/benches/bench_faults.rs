//! Bench: **fault injection + degraded-mode serving** — the
//! deterministic chaos harness over the continuous-batching runtime.
//!
//! Acceptance gates (asserted, not just printed):
//!
//! 1. an **empty fault plan is observationally free**: a run with a
//!    zero-event injector attached produces a report fingerprint
//!    byte-identical to a run with no injector at all;
//! 2. a seeded **single-device-loss** run completes, and its goodput
//!    after the first fault retains at least the surviving capacity
//!    fraction minus 10 points (half the pool dies ⇒ goodput under
//!    fault ≥ 40% of post-fault submissions at this load);
//! 3. the **conservation ledger never leaks under faults**: submitted
//!    == completed + failed + expired + shed + rejected in every mode,
//!    storms included — retries re-enter forming without re-counting
//!    submission;
//! 4. fault runs are **deterministic**: two identically-seeded
//!    device-loss runs (and two identically-seeded storm runs) produce
//!    byte-identical fingerprints.
//!
//! The runtime is deterministic (logical clock + calibrated cycle
//! models), so these gates are CI-stable; host wall time is reported in
//! `BENCH_faults.json` (`wall_ns`) but never gated.
//!
//! ```bash
//! cargo bench --bench bench_faults            # full (192 requests/run)
//! cargo bench --bench bench_faults -- --quick # CI smoke (48 requests)
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    ArrivalGen, ArrivalKind, FeatureGen, RustGemmBackend, ServingConfig, ServingReport,
    ServingRuntime,
};
use versal_gemm::dl::MlpSpec;
use versal_gemm::fault::{FaultInjector, FaultPlan};
use versal_gemm::gemm::Precision;

const IN_DIM: usize = 256;

/// Replay one seeded open-loop trace through a runtime with the given
/// fault plan attached (`None` = no injector at all). Returns the
/// runtime (for report + fingerprint) and the host wall time.
fn drive(
    plan: Option<FaultPlan>,
    seed: u64,
    requests: usize,
) -> (ServingRuntime<RustGemmBackend>, u64) {
    let spec = MlpSpec { dims: vec![IN_DIM, 64] };
    let backend = RustGemmBackend::new(vc1902(), spec, 9, 4);
    let cfg = ServingConfig {
        max_batch: 4,
        max_wait_us: 200,
        queue_cap: 64,
        default_slo_us: 20_000,
        cache_budget_bytes: 64 << 20,
        plan_cache_budget_bytes: 8 << 20,
        pipeline_devices: 2,
        max_backlog_us: 5_000,
    };
    let mut rt = ServingRuntime::new(backend, cfg);
    if let Some(p) = plan {
        rt = rt.with_faults(FaultInjector::new(p));
    }
    let mut features = FeatureGen::new(IN_DIM, seed ^ 0xFEA7);
    let mut arrivals = ArrivalGen::new(ArrivalKind::Poisson.process(4_000.0, 1.0), seed);
    let t0 = std::time::Instant::now();
    let mut last_us = 0u64;
    for _ in 0..requests {
        last_us = (arrivals.next_arrival() * 1e6) as u64;
        let _ = rt.submit(features.next(), Precision::U8, last_us);
        rt.tick(last_us);
    }
    rt.drain(last_us + 1_000);
    (rt, t0.elapsed().as_nanos() as u64)
}

/// The conservation ledger of a report: (submitted, sum of terminal
/// states). Every submission must reach exactly one terminal state.
fn ledger(r: &ServingReport) -> (u64, u64) {
    let submitted: u64 = r.tenants.iter().map(|t| t.submitted).sum();
    (submitted, r.completed + r.failed + r.expired + r.shed + r.rejected)
}

fn assert_conserved(label: &str, r: &ServingReport) {
    let (submitted, terminal) = ledger(r);
    assert_eq!(
        submitted, terminal,
        "GATE ({label}): ledger leak — {submitted} submitted vs {terminal} terminal"
    );
}

fn json_row(label: &str, r: &ServingReport, wall_ns: u64) -> String {
    let (submitted, _) = ledger(r);
    let f = r.faults.clone().unwrap_or_default();
    format!(
        "{{\"mode\":\"{label}\",\"submitted\":{submitted},\"completed\":{},\
         \"failed\":{},\"expired\":{},\"shed\":{},\"rejected\":{},\
         \"faults_injected\":{},\"transient_failures\":{},\"retries\":{},\
         \"retry_exhausted\":{},\"recoveries\":{},\"mttr_cycles\":{},\
         \"capacity_fraction\":{:.4},\"goodput_after_fault\":{:.4},\
         \"wall_ns\":{wall_ns}}}",
        r.completed,
        r.failed,
        r.expired,
        r.shed,
        r.rejected,
        f.injected,
        f.transient_failures,
        f.retries,
        f.retry_exhausted,
        f.recoveries,
        f.mttr_cycles,
        f.capacity_fraction,
        f.goodput_after_fault(),
    )
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let requests = if quick { 48 } else { 192 };
    let seed = 1717u64;

    println!("=== fault injection: degraded-mode serving under seeded faults ===");
    println!(
        "(MLP {IN_DIM}→64 on 4 tiles; {requests} Poisson requests @ 4 000/s, 2 pipeline \
         devices{})\n",
        if quick { " [quick]" } else { "" }
    );

    // --- gate 1: the empty plan is observationally free ---------------
    let (healthy, wall_healthy) = drive(None, seed, requests);
    let (empty, wall_empty) = drive(Some(FaultPlan::none()), seed, requests);
    let rep_healthy = healthy.report();
    assert!(rep_healthy.completed > 0, "baseline must serve requests");
    assert_conserved("healthy", &rep_healthy);
    assert_eq!(
        healthy.fingerprint(),
        empty.fingerprint(),
        "GATE: an empty fault plan must be byte-invisible in the fingerprint"
    );
    println!(
        "healthy baseline: {} completed; empty-plan run byte-identical",
        rep_healthy.completed
    );

    // --- gates 2 + 4: seeded single-device loss ------------------------
    let loss_plan = FaultPlan::single_device_loss(1, 10_000);
    let (loss_a, wall_loss) = drive(Some(loss_plan.clone()), seed, requests);
    let (loss_b, _) = drive(Some(loss_plan), seed, requests);
    assert_eq!(
        loss_a.fingerprint(),
        loss_b.fingerprint(),
        "GATE: identically-seeded device-loss runs must be byte-identical"
    );
    let rep_loss = loss_a.report();
    assert_conserved("device_loss", &rep_loss);
    let f = rep_loss.faults.clone().expect("injector attached");
    assert_eq!(f.injected, 1, "exactly the scheduled device loss fired");
    assert!(rep_loss.completed > 0, "the degraded pool must keep serving");
    let retention = f.goodput_after_fault();
    let floor = (f.capacity_fraction - 0.10).max(0.0);
    println!(
        "device loss @10ms: capacity {:.0}%, goodput after fault {:.1}% of {} \
         post-fault submissions (floor {:.0}%)",
        f.capacity_fraction * 100.0,
        retention * 100.0,
        f.submitted_after_fault,
        floor * 100.0
    );
    assert!(
        f.submitted_after_fault > 0,
        "the trace must extend past the injected fault"
    );
    assert!(
        retention >= floor,
        "GATE: goodput under fault {retention:.3} must retain the surviving capacity \
         fraction {:.3} minus 10 points",
        f.capacity_fraction
    );

    // --- gates 3 + 4: seeded fault storm -------------------------------
    let storm_plan = FaultPlan::storm(seed, 40_000, 6, 2);
    let (storm_a, wall_storm) = drive(Some(storm_plan.clone()), seed, requests);
    let (storm_b, _) = drive(Some(storm_plan), seed, requests);
    assert_eq!(
        storm_a.fingerprint(),
        storm_b.fingerprint(),
        "GATE: identically-seeded storm runs must be byte-identical"
    );
    let rep_storm = storm_a.report();
    assert_conserved("storm", &rep_storm);
    let fs = rep_storm.faults.clone().expect("injector attached");
    println!(
        "storm (6 events / 40ms horizon): {} injected, {} transient failures, {} retries \
         ({} exhausted), ledger conserved",
        fs.injected, fs.transient_failures, fs.retries, fs.retry_exhausted
    );

    // --- machine-readable artifact: BENCH_faults.json ------------------
    let json = format!(
        "{{\"bench\":\"faults\",\"schema\":\"faults-v1\",\"quick\":{quick},\
         \"requests\":{requests},\"seed\":{seed},\
         \"rows\":[{},{},{},{}],\
         \"goodput_after_fault\":{:.4},\"capacity_fraction\":{:.4},\
         \"retention_floor\":{:.4},\
         \"empty_plan_identical\":true,\"seeded_runs_identical\":true}}\n",
        json_row("healthy", &rep_healthy, wall_healthy),
        json_row("empty_plan", &empty.report(), wall_empty),
        json_row("device_loss", &rep_loss, wall_loss),
        json_row("storm", &rep_storm, wall_storm),
        retention,
        f.capacity_fraction,
        floor,
    );
    let dir = std::path::PathBuf::from(
        std::env::var_os("VERSAL_BENCH_RESULTS").unwrap_or_else(|| "bench_results".into()),
    );
    std::fs::create_dir_all(&dir).expect("create bench results dir");
    let path = dir.join("BENCH_faults.json");
    std::fs::write(&path, &json).expect("write BENCH_faults.json");
    println!("\nwrote {}", path.display());
    println!("all fault gates passed.");
}
