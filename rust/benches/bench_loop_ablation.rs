//! Bench: **loop-parallelisation ablation** (§4.4 quantified).
//!
//! The paper selects loop L4 by architectural argument; this harness runs
//! the cost model for parallelising L1, L3, L4 and L5 across 1–32 tiles
//! (L2/L6 are rejected for the paper's race-condition reason) and prints
//! the speedup matrix, making the argument an experiment.
//!
//! ```bash
//! cargo bench --bench bench_loop_ablation
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::ablation::{evaluate, LoopChoice};
use versal_gemm::gemm::GemmConfig;
use versal_gemm::util::tabulate::{Align, Table};

fn main() {
    let arch = vc1902();
    let tile_counts = [1usize, 2, 4, 8, 16, 32];

    println!("=== loop-parallelisation ablation, (mc, nc, kc) = (256, 256, 2048) ===\n");
    println!("total cycles (lower is better):\n");
    let mut t = Table::new(&["loop \\ tiles", "1", "2", "4", "8", "16", "32"]).align(0, Align::Left);
    let mut speedups: Vec<(LoopChoice, f64)> = Vec::new();
    for choice in LoopChoice::PARALLELISABLE {
        let mut row = vec![choice.name().to_string()];
        let mut t1 = None;
        let mut t32 = None;
        for &n in &tile_counts {
            match evaluate(&arch, &GemmConfig::paper_table2(n), choice) {
                Ok(r) => {
                    if n == 1 {
                        t1 = Some(r.total_cycles as f64);
                    }
                    if n == 32 {
                        t32 = Some(r.total_cycles as f64);
                    }
                    row.push(format!("{:.0}e3", r.total_cycles as f64 / 1e3));
                }
                Err(_) => row.push("-".to_string()),
            }
        }
        if let (Some(a), Some(b)) = (t1, t32) {
            speedups.push((choice, a / b));
        }
        t.row(&row);
    }
    println!("{}", t.to_text());

    println!("race-excluded loops (§4.4):");
    for choice in [LoopChoice::L2, LoopChoice::L6] {
        let err = evaluate(&arch, &GemmConfig::paper_table2(4), choice).unwrap_err();
        println!("  {}: {err}", choice.name());
    }

    println!("\nspeedup at 32 tiles:");
    speedups.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    for (c, s) in &speedups {
        let marker = if *c == LoopChoice::L4 { "  ← paper's choice" } else { "" };
        println!("  {:8} {s:5.1}×{marker}", c.name());
    }
    assert_eq!(speedups[0].0, LoopChoice::L4, "L4 must win on this memory organisation");
    println!("\nL4 wins — matching §4.4's argument for private-L1 / shared-L2+L3 platforms.");
}
