//! Bench: device-level strong scaling — **Table 2, one level up**.
//!
//! Shards the paper's fixed problem (m, n, k) = (256, 256, 2048) SUMMA-
//! style across homogeneous ring clusters of 1/2/4/8 simulated VC1902s
//! and reports a Table-2-shaped scaling table (aggregate MACs/cycle and
//! per-device efficiency), plus a tile-count sweep and a bit-exactness
//! check of the sharded numerics against the naive oracle.
//!
//! Acceptance gates (asserted, not just printed):
//!  - aggregate MACs/cycle strictly increases from 1 → 4 devices;
//!  - per-device efficiency stays ≥ 70% of the single-device figure.
//!
//! ```bash
//! cargo bench --bench bench_cluster_scaling            # full sweep
//! cargo bench --bench bench_cluster_scaling -- --quick # CI smoke
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::cluster::{Cluster, ClusterGemm, ClusterGemmConfig, FabricSpec};
use versal_gemm::gemm::baseline::naive_gemm;
use versal_gemm::gemm::{Ccp, MatI32, MatU8};
use versal_gemm::report;
use versal_gemm::util::Pcg32;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let arch = vc1902();
    let fabric = FabricSpec::pcie_like();

    // ---- numerics: sharded == naive on non-square shapes -------------
    println!("=== sharded-GEMM numerics (vs naive oracle) ===\n");
    let shapes = [(48usize, 96usize, 40usize), (33, 57, 29), (12, 160, 24)];
    for &(m, k, n) in &shapes {
        for devices in [2usize, 4] {
            let cluster = Cluster::vc1902_pool(devices, 3).expect("pool");
            let engine = ClusterGemm::new(&cluster);
            let mut rng = Pcg32::new((m * k * n) as u64);
            let a = MatU8::random(m, k, &mut rng);
            let b = MatU8::random(k, n, &mut rng);
            let mut want = MatI32::zeros(m, n);
            naive_gemm(&a, &b, &mut want);
            let mut c = MatI32::zeros(m, n);
            let cfg = ClusterGemmConfig::with_ccp(Ccp { mc: 16, nc: 16, kc: 32 });
            engine.run_auto(&cfg, &a, &b, &mut c).expect("sharded run");
            let diff = c.max_abs_diff(&want);
            println!(
                "  ({m:>3}, {k:>3}, {n:>3}) on {devices} devices: max |Δ| = {diff}  {}",
                if diff == 0 { "EXACT" } else { "MISMATCH" }
            );
            assert_eq!(diff, 0, "sharded GEMM must be bit-exact");
        }
    }

    // ---- the scaling table -------------------------------------------
    let device_counts: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8] };
    let tiles = 8;
    println!("\n=== device-level strong scaling, {tiles} AIE tiles/device, {} fabric ===\n", fabric.name);
    let rows = report::cluster_scaling_rows(&arch, tiles, device_counts, &fabric)
        .expect("scaling rows");
    let table = report::cluster_table(&rows);
    println!("{}", table.to_text());
    if let Ok(path) = report::save_csv("cluster_scaling", &table) {
        println!("(csv: {})\n", path.display());
    }

    // ---- acceptance gates --------------------------------------------
    let through_four: Vec<_> = rows.iter().filter(|r| r.devices <= 4).collect();
    for w in through_four.windows(2) {
        assert!(
            w[1].aggregate_macs_per_cycle > w[0].aggregate_macs_per_cycle,
            "aggregate MACs/cycle must rise {}→{} devices: {:.1} vs {:.1}",
            w[0].devices,
            w[1].devices,
            w[0].aggregate_macs_per_cycle,
            w[1].aggregate_macs_per_cycle
        );
    }
    for r in &through_four {
        assert!(
            r.per_device_efficiency >= 0.70,
            "devices={}: per-device efficiency {:.1}% < 70%",
            r.devices,
            r.per_device_efficiency * 100.0
        );
    }
    println!(
        "PASS: aggregate MACs/cycle monotone over {:?} devices",
        through_four.iter().map(|r| r.devices).collect::<Vec<_>>()
    );
    println!(
        "PASS: per-device efficiency ≥ 70% through 4 devices (worst {:.1}%)",
        through_four
            .iter()
            .map(|r| r.per_device_efficiency)
            .fold(f64::INFINITY, f64::min)
            * 100.0
    );

    // ---- tile-count sweep (insight: strong-scaling wall) -------------
    if !quick {
        println!("\n=== devices × tiles/device (aggregate MACs/cycle) ===\n");
        for tiles in [2usize, 8, 32] {
            let rows = report::cluster_scaling_rows(&arch, tiles, &[1, 2, 4, 8], &fabric)
                .expect("sweep rows");
            let line: Vec<String> = rows
                .iter()
                .map(|r| format!("{}dev {:.0}", r.devices, r.aggregate_macs_per_cycle))
                .collect();
            println!("  tiles/dev {tiles:>2}: {}", line.join("   "));
        }
        println!(
            "\n(small shards cannot feed 32 tiles/device — the device-level\n\
             analogue of the paper's L4 observation that parallelism is\n\
             bounded by nc/nr micro-panels.)"
        );
    }
}
