//! Bench: the mixed-precision micro-kernel suite on the Table-2 problem.
//!
//! Evaluates all four precisions (u8, i8, i16, bf16) of the §4.2 kernel
//! family on the paper's fixed problem (m, n, k) = (256, 256, 2048),
//! each under its own feasible paper-shaped CCP, and prints a
//! Table-2-style comparison (per-kernel and whole-problem MACs/cycle),
//! plus a numerics spot-check of every precision against the golden
//! reference and the tuner's adaptive selection across accuracy budgets.
//!
//! Acceptance gates (asserted, not just printed):
//!  - throughput ordering u8 ≥ i16 ≥ bf16, exactly what the per-precision
//!    cycle model predicts (128/32/16 MACs per vector op, 1-byte vs
//!    2-byte Ar streams);
//!  - integer precisions bit-exact vs the naive reference on an edge
//!    shape; bf16 within the f32 forward-error bound;
//!  - the adaptive tuner picks u8 at loose budgets and bf16 at tight
//!    ones, deterministically.
//!
//! ```bash
//! cargo bench --bench bench_mixed_precision            # full run
//! cargo bench --bench bench_mixed_precision -- --quick # CI smoke
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::baseline::naive_gemm_p;
use versal_gemm::gemm::{
    bf16_forward_error_bound, select_precision, Bf16, Ccp, Element, GemmConfig, Mat,
    ParallelGemm, Precision,
};
use versal_gemm::report;
use versal_gemm::util::Pcg32;

fn numerics_spot_check<T: Element>(engine: &ParallelGemm<'_>, cfg: &GemmConfig) -> f64 {
    let (m, k, n) = (21, 37, 13); // edge shape: nothing divides MR/NR/kc
    let mut rng = Pcg32::new(0xBE7C);
    let a = Mat::<T>::random(m, k, &mut rng);
    let b = Mat::<T>::random(k, n, &mut rng);
    let mut c = Mat::<T::Acc>::zeros(m, n);
    let mut want = Mat::<T::Acc>::zeros(m, n);
    engine.run_p::<T>(cfg, &a, &b, &mut c).expect("run_p");
    naive_gemm_p::<T>(&a, &b, &mut want);
    c.max_abs_diff_f64(&want)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let arch = vc1902();
    let tiles = 8;

    // ---- numerics: every precision vs the golden reference ----------
    println!("=== per-precision numerics (edge shape (21, 37, 13) vs golden reference) ===\n");
    let engine = ParallelGemm::new(&arch);
    let mut cfg = GemmConfig::paper_table2(4);
    cfg.ccp = Ccp { mc: 16, nc: 16, kc: 32 };
    let d_u8 = numerics_spot_check::<u8>(&engine, &cfg);
    let d_i8 = numerics_spot_check::<i8>(&engine, &cfg);
    let d_i16 = numerics_spot_check::<i16>(&engine, &cfg);
    let d_bf16 = numerics_spot_check::<Bf16>(&engine, &cfg);
    println!("  u8   max |Δ| = {d_u8}   {}", if d_u8 == 0.0 { "EXACT" } else { "MISMATCH" });
    println!("  i8   max |Δ| = {d_i8}   {}", if d_i8 == 0.0 { "EXACT" } else { "MISMATCH" });
    println!("  i16  max |Δ| = {d_i16}   {}", if d_i16 == 0.0 { "EXACT" } else { "MISMATCH" });
    // bf16: |Δ| vs the *f32-association* reference is itself f32-rounding
    // noise; the proven f64 bound lives in tests/precision_conformance.rs.
    // Values are in [-1, 1], so Σ|a·b| ≤ k; both sides compute in f32,
    // hence the two-sided factor.
    let bf16_bound = 2.0 * bf16_forward_error_bound(37, 37.0);
    println!("  bf16 max |Δ| = {d_bf16:.3e} (bound {bf16_bound:.3e})");
    assert_eq!(d_u8, 0.0, "u8 must be bit-exact");
    assert_eq!(d_i8, 0.0, "i8 must be bit-exact");
    assert_eq!(d_i16, 0.0, "i16 must be bit-exact");
    assert!(d_bf16 <= bf16_bound, "bf16 out of bound: {d_bf16} > {bf16_bound}");

    // ---- the precision comparison table ------------------------------
    let (m, n, k) = report::TABLE2_PROBLEM;
    println!("\n=== mixed-precision suite, ({m}, {n}, {k}) on {tiles} AIE tiles ===\n");
    let rows = report::precision_rows(&arch, tiles);
    let table = report::precision_table(&rows);
    println!("{}", table.to_text());
    if let Ok(path) = report::save_csv("mixed_precision", &table) {
        println!("(csv: {})\n", path.display());
    }

    // ---- acceptance gate: the cycle model's throughput ordering ------
    let get = |p: Precision| {
        rows.iter().find(|r| r.precision == p).expect("row").aggregate_macs_per_cycle
    };
    let (t_u8, t_i16, t_bf16) =
        (get(Precision::U8), get(Precision::I16), get(Precision::Bf16));
    assert!(
        t_u8 >= t_i16 && t_i16 >= t_bf16,
        "throughput ordering violated: u8 {t_u8:.1} / i16 {t_i16:.1} / bf16 {t_bf16:.1}"
    );
    println!(
        "PASS: throughput ordering u8 ({t_u8:.1}) ≥ i16 ({t_i16:.1}) ≥ bf16 ({t_bf16:.1}) \
         MACs/cycle on the Table-2 problem"
    );

    // ---- adaptive selection ------------------------------------------
    println!("\n=== adaptive precision selection (accuracy budget sweep) ===\n");
    let loose = select_precision(&arch, m, n, k, tiles, 0.5).expect("loose budget");
    let tight = select_precision(&arch, m, n, k, tiles, 1e-4).expect("tight budget");
    for (budget, c) in [(0.5, &loose), (1e-4, &tight)] {
        println!(
            "  budget {budget:<7.0e} → {:<5} ({} cycles, rel err {:.1e})",
            c.precision.to_string(),
            c.predicted_cycles,
            c.predicted_rel_error
        );
    }
    assert_eq!(loose.precision, Precision::U8, "loose budget must pick u8");
    assert_eq!(tight.precision, Precision::Bf16, "tight budget must pick bf16");
    let again = select_precision(&arch, m, n, k, tiles, 1e-4).expect("tight budget, rerun");
    assert_eq!(again.precision, tight.precision, "selection must be deterministic");
    assert_eq!(again.predicted_cycles, tight.predicted_cycles);
    println!("\nPASS: u8 at loose budgets, bf16 at tight budgets, deterministically");

    // ---- full sweep: tile scaling per precision (skipped in quick) ---
    if !quick {
        println!("\n=== aggregate MACs/cycle vs tiles, per precision ===\n");
        for t in [1usize, 4, 16, 32] {
            let rows = report::precision_rows(&arch, t);
            let line: Vec<String> = rows
                .iter()
                .map(|r| format!("{} {:.1}", r.precision, r.aggregate_macs_per_cycle))
                .collect();
            println!("  tiles {t:>2}: {}", line.join("   "));
        }
        println!(
            "\n(the integer/bf16 gap narrows with tiles — the serial Cr port\n\
             hurts wide accumulators most at high tile counts.)"
        );
    }
}
