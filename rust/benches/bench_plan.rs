//! Bench: **plan-IR parity** — the unified execution plan's predicted
//! schedule against the cycles the drivers actually execute, plus the
//! per-level footprint accounting, emitted machine-readably as
//! `BENCH_plan.json` so CI accumulates a perf trajectory.
//!
//! Acceptance gates (asserted, not just printed):
//!
//! 1. for every case, `GemmPlan::cost` **equals** the executed
//!    [`ParallelGemm::run_p`] cycles bit-for-bit — predicted and
//!    executed schedules are the same plan by construction;
//! 2. plan-effective MAC totals equal `BlockedGemm::total_macs`
//!    (`m·n·k`) — the lowered extents partition the iteration space;
//! 3. every per-level peak footprint fits its budget (the plan
//!    validated it; the JSON records the utilisations);
//! 4. the streaming `PlanSpec::cost_streaming` fold prices the same
//!    schedule as the materialized plan, bit-for-bit — the tuner's
//!    allocation-free path cannot drift from what executes.
//!
//! Each JSON case additionally records `lower_ns` (host wall-time of
//! the materializing lowering) and `step_bytes` (the transient step
//! vector's byte footprint — exactly what the streaming path avoids),
//! so CI artifacts track the lowering cost the plan cache and the
//! streaming fold exist to kill.
//!
//! ```bash
//! cargo bench --bench bench_plan            # full (incl. Table-2 shape)
//! cargo bench --bench bench_plan -- --quick # CI smoke
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::precision::Bf16;
use versal_gemm::gemm::{
    BlockedGemm, Ccp, Element, GemmConfig, Mat, ParallelGemm, Precision,
};
use versal_gemm::plan::{GemmPlan, PlanSpec};
use versal_gemm::util::Pcg32;

struct Case {
    m: usize,
    n: usize,
    k: usize,
    precision: Precision,
    ccp: Ccp,
    tiles: usize,
    predicted: u64,
    executed: u64,
    macs: u64,
    lower_ns: u64,
    step_bytes: u64,
    footprints: String,
}

fn run_case<T: Element>(
    arch: &versal_gemm::VersalArch,
    m: usize,
    n: usize,
    k: usize,
    ccp: Ccp,
    tiles: usize,
    seed: u64,
) -> Case {
    let prec = T::PRECISION;
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.ccp = ccp;
    let t0 = std::time::Instant::now();
    let plan = GemmPlan::lower(arch, &cfg, m, n, k, prec, false)
        .expect("bench case must lower (feasible by construction)");
    let lower_ns = t0.elapsed().as_nanos() as u64;
    let step_bytes = plan.step_bytes();
    let predicted = plan.cost(arch);

    // --- gate 4: the streaming fold prices the identical schedule -----
    let spec = PlanSpec::new(arch, &cfg, m, n, k, prec, false)
        .expect("spec validates whenever lowering succeeds");
    assert_eq!(
        spec.cost_streaming(arch),
        predicted,
        "GATE: streaming cost must equal materialized cost for ({m}, {n}, {k}) {prec}"
    );

    let mut rng = Pcg32::new(seed);
    let a = Mat::<T>::random(m, k, &mut rng);
    let b = Mat::<T>::random(k, n, &mut rng);
    let mut c = Mat::<T::Acc>::zeros(m, n);
    let engine = ParallelGemm::new(arch);
    let (executed, _) = engine.run_p::<T>(&cfg, &a, &b, &mut c).expect("bench case runs");

    // --- gate 1: predicted == executed, bit-for-bit ------------------
    assert_eq!(
        predicted, executed,
        "GATE: plan cost must equal executed cycles for ({m}, {n}, {k}) {prec}"
    );
    // --- gate 2: effective MACs are conserved ------------------------
    assert_eq!(
        plan.total_macs(),
        BlockedGemm::total_macs(m, n, k),
        "GATE: plan MACs must equal m*n*k"
    );
    // --- gate 3: footprints fit (lowering validated; record them) ----
    let footprints = plan
        .footprints()
        .iter()
        .map(|fp| {
            assert!(
                fp.peak_bytes <= fp.budget_bytes(),
                "GATE: {:?} oversubscribed after lowering",
                fp.level
            );
            format!(
                "{{\"level\":\"{}\",\"peak_bytes\":{},\"budget_bytes\":{},\"capacity_bytes\":{},\"utilisation\":{:.6}}}",
                fp.level.cache_analogue(),
                fp.peak_bytes,
                fp.budget_bytes(),
                fp.capacity_bytes,
                fp.utilisation()
            )
        })
        .collect::<Vec<_>>()
        .join(",");

    Case {
        m,
        n,
        k,
        precision: prec,
        ccp,
        tiles,
        predicted: predicted.total,
        executed: executed.total,
        macs: plan.total_macs(),
        lower_ns,
        step_bytes,
        footprints,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let arch = vc1902();

    println!("=== plan IR: predicted vs executed schedule parity ===");
    println!("(every row asserts plan.cost == ParallelGemm cycles bit-for-bit{})\n",
        if quick { " [quick]" } else { "" });

    let small = Ccp { mc: 32, nc: 32, kc: 64 };
    let mut cases = vec![
        run_case::<u8>(&arch, 96, 80, 160, small, 4, 0xB1),
        run_case::<i8>(&arch, 63, 49, 97, small, 3, 0xB2),
        run_case::<i16>(&arch, 48, 40, 80, small, 2, 0xB3),
        run_case::<Bf16>(&arch, 40, 33, 65, small, 2, 0xB4),
    ];
    if !quick {
        // The paper's Table-2 problem, at the paper's CCP.
        cases.push(run_case::<u8>(
            &arch,
            256,
            256,
            2048,
            Ccp { mc: 256, nc: 256, kc: 2048 },
            8,
            0xB5,
        ));
    }

    println!(
        "{:<28} {:>6} {:>14} {:>14} {:>12} {:>12} {:>12}",
        "case", "tiles", "predicted", "executed", "MACs/cycle", "lower µs", "step bytes"
    );
    for c in &cases {
        println!(
            "{:<28} {:>6} {:>14} {:>14} {:>12.1} {:>12.1} {:>12}",
            format!("({}, {}, {}) {}", c.m, c.n, c.k, c.precision),
            c.tiles,
            c.predicted,
            c.executed,
            c.macs as f64 / c.executed as f64,
            c.lower_ns as f64 / 1e3,
            c.step_bytes,
        );
    }

    // --- machine-readable artifact: BENCH_plan.json ------------------
    let json_cases = cases
        .iter()
        .map(|c| {
            format!(
                "{{\"m\":{},\"n\":{},\"k\":{},\"precision\":\"{}\",\"mc\":{},\"nc\":{},\"kc\":{},\
                 \"tiles\":{},\"predicted_cycles\":{},\"executed_cycles\":{},\"macs\":{},\
                 \"macs_per_cycle\":{:.4},\"lower_ns\":{},\"step_bytes\":{},\"footprints\":[{}]}}",
                c.m,
                c.n,
                c.k,
                c.precision,
                c.ccp.mc,
                c.ccp.nc,
                c.ccp.kc,
                c.tiles,
                c.predicted,
                c.executed,
                c.macs,
                c.macs as f64 / c.executed as f64,
                c.lower_ns,
                c.step_bytes,
                c.footprints
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    let json = format!(
        "{{\"bench\":\"plan\",\"quick\":{quick},\"parity\":\"exact\",\"cases\":[{json_cases}]}}\n"
    );
    let dir = std::path::PathBuf::from(
        std::env::var_os("VERSAL_BENCH_RESULTS").unwrap_or_else(|| "bench_results".into()),
    );
    std::fs::create_dir_all(&dir).expect("create bench results dir");
    let path = dir.join("BENCH_plan.json");
    std::fs::write(&path, &json).expect("write BENCH_plan.json");
    println!("\nwrote {}", path.display());
    println!(
        "all plan gates passed (predicted == executed and streaming == materialized \
         on every case)."
    );
}
