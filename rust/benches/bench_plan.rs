//! Bench: **plan-IR parity** — the unified execution plan's predicted
//! schedule against the cycles the drivers actually execute, plus the
//! per-level footprint accounting, emitted machine-readably as
//! `BENCH_plan.json` so CI accumulates a perf trajectory.
//!
//! Acceptance gates (asserted, not just printed):
//!
//! 1. for every case, `GemmPlan::cost` **equals** the executed
//!    [`ParallelGemm::run_p`] cycles bit-for-bit — predicted and
//!    executed schedules are the same plan by construction;
//! 2. plan-effective MAC totals equal `BlockedGemm::total_macs`
//!    (`m·n·k`) — the lowered extents partition the iteration space;
//! 3. every per-level peak footprint fits its budget (the plan
//!    validated it; the JSON records the utilisations);
//! 4. the streaming `PlanSpec::cost_streaming` fold prices the same
//!    schedule as the materialized plan, bit-for-bit — the tuner's
//!    allocation-free path cannot drift from what executes.
//!
//! Each JSON case additionally records `lower_ns` (host wall-time of
//! the materializing lowering), `wall_ns` (host wall-time of the
//! executed run — first-class next to model cycles, never gated by
//! bench-trend) and `step_bytes` (the transient step vector's byte
//! footprint — exactly what the streaming path avoids), so CI
//! artifacts track the lowering cost the plan cache and the streaming
//! fold exist to kill.
//!
//! A final `engine_speedup` block runs the same shape through the
//! sequential reference engine and the 8-worker work-stealing pool:
//! gate 5 asserts the pooled result is **bit-identical** (C, cycles)
//! — the deterministic-reduction invariant — and, on machines with
//! at least 4 hardware threads in full mode, that the pooled wall
//! time beats sequential by >1.5×.
//!
//! ```bash
//! cargo bench --bench bench_plan            # full (incl. Table-2 shape)
//! cargo bench --bench bench_plan -- --quick # CI smoke
//! ```

use std::sync::Arc;
use versal_gemm::arch::vc1902;
use versal_gemm::gemm::precision::Bf16;
use versal_gemm::gemm::{
    BlockedGemm, Ccp, Element, GemmConfig, Mat, ParallelGemm, Precision,
};
use versal_gemm::plan::{GemmPlan, PlanSpec};
use versal_gemm::runtime::ThreadPool;
use versal_gemm::util::Pcg32;

struct Case {
    m: usize,
    n: usize,
    k: usize,
    precision: Precision,
    ccp: Ccp,
    tiles: usize,
    predicted: u64,
    executed: u64,
    macs: u64,
    lower_ns: u64,
    wall_ns: u64,
    step_bytes: u64,
    footprints: String,
}

fn run_case<T: Element>(
    arch: &versal_gemm::VersalArch,
    m: usize,
    n: usize,
    k: usize,
    ccp: Ccp,
    tiles: usize,
    seed: u64,
) -> Case {
    let prec = T::PRECISION;
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.ccp = ccp;
    let t0 = std::time::Instant::now();
    let plan = GemmPlan::lower(arch, &cfg, m, n, k, prec, false)
        .expect("bench case must lower (feasible by construction)");
    let lower_ns = t0.elapsed().as_nanos() as u64;
    let step_bytes = plan.step_bytes();
    let predicted = plan.cost(arch);

    // --- gate 4: the streaming fold prices the identical schedule -----
    let spec = PlanSpec::new(arch, &cfg, m, n, k, prec, false)
        .expect("spec validates whenever lowering succeeds");
    assert_eq!(
        spec.cost_streaming(arch),
        predicted,
        "GATE: streaming cost must equal materialized cost for ({m}, {n}, {k}) {prec}"
    );

    let mut rng = Pcg32::new(seed);
    let a = Mat::<T>::random(m, k, &mut rng);
    let b = Mat::<T>::random(k, n, &mut rng);
    let mut c = Mat::<T::Acc>::zeros(m, n);
    let engine = ParallelGemm::new(arch);
    let t1 = std::time::Instant::now();
    let (executed, _) = engine.run_p::<T>(&cfg, &a, &b, &mut c).expect("bench case runs");
    let wall_ns = t1.elapsed().as_nanos() as u64;

    // --- gate 1: predicted == executed, bit-for-bit ------------------
    assert_eq!(
        predicted, executed,
        "GATE: plan cost must equal executed cycles for ({m}, {n}, {k}) {prec}"
    );
    // --- gate 2: effective MACs are conserved ------------------------
    assert_eq!(
        plan.total_macs(),
        BlockedGemm::total_macs(m, n, k),
        "GATE: plan MACs must equal m*n*k"
    );
    // --- gate 3: footprints fit (lowering validated; record them) ----
    let footprints = plan
        .footprints()
        .iter()
        .map(|fp| {
            assert!(
                fp.peak_bytes <= fp.budget_bytes(),
                "GATE: {:?} oversubscribed after lowering",
                fp.level
            );
            format!(
                "{{\"level\":\"{}\",\"peak_bytes\":{},\"budget_bytes\":{},\"capacity_bytes\":{},\"utilisation\":{:.6}}}",
                fp.level.cache_analogue(),
                fp.peak_bytes,
                fp.budget_bytes(),
                fp.capacity_bytes,
                fp.utilisation()
            )
        })
        .collect::<Vec<_>>()
        .join(",");

    Case {
        m,
        n,
        k,
        precision: prec,
        ccp,
        tiles,
        predicted: predicted.total,
        executed: executed.total,
        macs: plan.total_macs(),
        lower_ns,
        wall_ns,
        step_bytes,
        footprints,
    }
}

/// Gate 5: sequential vs 8-worker pooled engine on one shape — the
/// pooled walk must be bit-identical in C and cycles; wall times are
/// recorded (and, in full mode on ≥4-thread machines, gated >1.5×).
struct EngineSpeedup {
    m: usize,
    n: usize,
    k: usize,
    workers: usize,
    seq_wall_ns: u64,
    pool_wall_ns: u64,
}

impl EngineSpeedup {
    fn speedup(&self) -> f64 {
        self.seq_wall_ns as f64 / self.pool_wall_ns.max(1) as f64
    }
}

fn run_engine_speedup(
    arch: &versal_gemm::VersalArch,
    m: usize,
    n: usize,
    k: usize,
    ccp: Ccp,
    tiles: usize,
    seed: u64,
) -> EngineSpeedup {
    let workers = 8;
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.ccp = ccp;
    let mut rng = Pcg32::new(seed);
    let a = Mat::<u8>::random(m, k, &mut rng);
    let b = Mat::<u8>::random(k, n, &mut rng);

    let mut c_seq = Mat::<i32>::zeros(m, n);
    let seq = ParallelGemm::new(arch);
    let t0 = std::time::Instant::now();
    let (cy_seq, st_seq) = seq.run_p::<u8>(&cfg, &a, &b, &mut c_seq).expect("seq runs");
    let seq_wall_ns = t0.elapsed().as_nanos() as u64;

    let mut c_pool = Mat::<i32>::zeros(m, n);
    let pooled = ParallelGemm::new(arch).with_pool(Arc::new(ThreadPool::new(workers)));
    let t1 = std::time::Instant::now();
    let (cy_pool, st_pool) =
        pooled.run_p::<u8>(&cfg, &a, &b, &mut c_pool).expect("pooled runs");
    let pool_wall_ns = t1.elapsed().as_nanos() as u64;

    // The deterministic-reduction invariant, asserted where the perf
    // number is produced: a speedup that changes bits is no speedup.
    assert_eq!(
        c_seq.data, c_pool.data,
        "GATE: pooled engine must be bit-identical to sequential on ({m}, {n}, {k})"
    );
    assert_eq!(cy_seq, cy_pool, "GATE: pooled cycle accounting must match sequential");
    assert_eq!(st_seq, st_pool, "GATE: pooled tile stats must match sequential");

    EngineSpeedup { m, n, k, workers, seq_wall_ns, pool_wall_ns }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let arch = vc1902();

    println!("=== plan IR: predicted vs executed schedule parity ===");
    println!("(every row asserts plan.cost == ParallelGemm cycles bit-for-bit{})\n",
        if quick { " [quick]" } else { "" });

    let small = Ccp { mc: 32, nc: 32, kc: 64 };
    let mut cases = vec![
        run_case::<u8>(&arch, 96, 80, 160, small, 4, 0xB1),
        run_case::<i8>(&arch, 63, 49, 97, small, 3, 0xB2),
        run_case::<i16>(&arch, 48, 40, 80, small, 2, 0xB3),
        run_case::<Bf16>(&arch, 40, 33, 65, small, 2, 0xB4),
    ];
    if !quick {
        // The paper's Table-2 problem, at the paper's CCP.
        cases.push(run_case::<u8>(
            &arch,
            256,
            256,
            2048,
            Ccp { mc: 256, nc: 256, kc: 2048 },
            8,
            0xB5,
        ));
    }

    println!(
        "{:<28} {:>6} {:>14} {:>14} {:>12} {:>12} {:>12} {:>12}",
        "case", "tiles", "predicted", "executed", "MACs/cycle", "lower µs", "wall µs", "step bytes"
    );
    for c in &cases {
        println!(
            "{:<28} {:>6} {:>14} {:>14} {:>12.1} {:>12.1} {:>12.1} {:>12}",
            format!("({}, {}, {}) {}", c.m, c.n, c.k, c.precision),
            c.tiles,
            c.predicted,
            c.executed,
            c.macs as f64 / c.executed as f64,
            c.lower_ns as f64 / 1e3,
            c.wall_ns as f64 / 1e3,
            c.step_bytes,
        );
    }

    // --- gate 5: cross-engine bit-exactness + wall-time speedup -------
    // Quick mode keeps the block (and the bit-exactness gate) on a
    // smaller shape so the JSON schema is identical; the >1.5× wall
    // gate only arms on the full run's Table-2 shape, and only when
    // the machine has the hardware threads to make it meaningful.
    let sp = if quick {
        run_engine_speedup(&arch, 96, 80, 160, small, 4, 0xE5)
    } else {
        run_engine_speedup(&arch, 256, 256, 2048, Ccp { mc: 256, nc: 256, kc: 2048 }, 8, 0xE5)
    };
    let hw_threads = std::thread::available_parallelism().map_or(1, |p| p.get());
    println!(
        "\nengine speedup ({}, {}, {}): sequential {:.2} ms, {}-worker pool {:.2} ms \
         — {:.2}x (bit-identical C, cycles, stats)",
        sp.m,
        sp.n,
        sp.k,
        sp.seq_wall_ns as f64 / 1e6,
        sp.workers,
        sp.pool_wall_ns as f64 / 1e6,
        sp.speedup()
    );
    if !quick && hw_threads >= 4 {
        assert!(
            sp.speedup() > 1.5,
            "GATE: {}-worker pool must beat sequential by >1.5x on the Table-2 shape \
             (got {:.2}x on a {hw_threads}-thread host)",
            sp.workers,
            sp.speedup()
        );
    }

    // --- machine-readable artifact: BENCH_plan.json ------------------
    let json_cases = cases
        .iter()
        .map(|c| {
            format!(
                "{{\"m\":{},\"n\":{},\"k\":{},\"precision\":\"{}\",\"mc\":{},\"nc\":{},\"kc\":{},\
                 \"tiles\":{},\"predicted_cycles\":{},\"executed_cycles\":{},\"macs\":{},\
                 \"macs_per_cycle\":{:.4},\"lower_ns\":{},\"wall_ns\":{},\"step_bytes\":{},\
                 \"footprints\":[{}]}}",
                c.m,
                c.n,
                c.k,
                c.precision,
                c.ccp.mc,
                c.ccp.nc,
                c.ccp.kc,
                c.tiles,
                c.predicted,
                c.executed,
                c.macs,
                c.macs as f64 / c.executed as f64,
                c.lower_ns,
                c.wall_ns,
                c.step_bytes,
                c.footprints
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    // Wall-time fields deliberately do not end in "cycles": bench-trend
    // gates the cycle domain only, and host wall time is machine-noise.
    let json = format!(
        "{{\"bench\":\"plan\",\"schema\":\"plan-v2\",\"quick\":{quick},\"parity\":\"exact\",\
         \"engine_speedup\":{{\"m\":{},\"n\":{},\"k\":{},\"workers\":{},\
         \"seq_wall_ns\":{},\"pool_wall_ns\":{},\"speedup\":{:.4},\"bit_exact\":true}},\
         \"cases\":[{json_cases}]}}\n",
        sp.m, sp.n, sp.k, sp.workers, sp.seq_wall_ns, sp.pool_wall_ns, sp.speedup()
    );
    let dir = std::path::PathBuf::from(
        std::env::var_os("VERSAL_BENCH_RESULTS").unwrap_or_else(|| "bench_results".into()),
    );
    std::fs::create_dir_all(&dir).expect("create bench results dir");
    let path = dir.join("BENCH_plan.json");
    std::fs::write(&path, &json).expect("write BENCH_plan.json");
    println!("\nwrote {}", path.display());
    println!(
        "all plan gates passed (predicted == executed, streaming == materialized, \
         pooled engine bit-identical on every case)."
    );
}
