//! Bench: **plan-IR parity** — the unified execution plan's predicted
//! schedule against the cycles the drivers actually execute, plus the
//! per-level footprint accounting, emitted machine-readably as
//! `BENCH_plan.json` so CI accumulates a perf trajectory.
//!
//! Acceptance gates (asserted, not just printed):
//!
//! 1. for every case, `GemmPlan::cost` **equals** the executed
//!    [`ParallelGemm::run_p`] cycles bit-for-bit — predicted and
//!    executed schedules are the same plan by construction;
//! 2. plan-effective MAC totals equal `BlockedGemm::total_macs`
//!    (`m·n·k`) — the lowered extents partition the iteration space;
//! 3. every per-level peak footprint fits its budget (the plan
//!    validated it; the JSON records the utilisations);
//! 4. the streaming `PlanSpec::cost_streaming` fold prices the same
//!    schedule as the materialized plan, bit-for-bit — the tuner's
//!    allocation-free path cannot drift from what executes.
//!
//! Each JSON case additionally records `lower_ns` (host wall-time of
//! the materializing lowering), `wall_ns` (host wall-time of the
//! executed run — first-class next to model cycles, never gated by
//! bench-trend), `pack_wall_ns` (host wall-time of the plan's serial
//! pack schedule alone — the slice parallel packing attacks) and
//! `step_bytes` (the transient step vector's byte footprint — exactly
//! what the streaming path avoids), so CI artifacts track the lowering
//! cost the plan cache and the streaming fold exist to kill.
//!
//! A final `engine_speedup` block runs the same shape through the
//! sequential reference engine, the 8-worker work-stealing pool, and
//! the pooled engine with a pack arena + parallel packing (the host
//! hot path): gate 5 asserts all pooled results are **bit-identical**
//! (C, cycles, stats) — the deterministic-reduction invariant — and,
//! on machines with at least 4 hardware threads in full mode, that
//! the pooled wall time beats sequential by >1.5× and the arena +
//! pack-parallel engine is strictly faster than the plain pooled
//! baseline (both cold, best-of-3).
//!
//! ```bash
//! cargo bench --bench bench_plan            # full (incl. Table-2 shape)
//! cargo bench --bench bench_plan -- --quick # CI smoke
//! ```

use std::sync::Arc;
use versal_gemm::arch::vc1902;
use versal_gemm::gemm::precision::Bf16;
use versal_gemm::gemm::{
    pack_a, pack_b, BlockedGemm, Ccp, Element, GemmConfig, Mat, ParallelGemm, Precision,
};
use versal_gemm::plan::{Buffer, GemmPlan, PlanSpec, PlanStep};
use versal_gemm::runtime::{PackArena, ThreadPool};
use versal_gemm::util::Pcg32;

struct Case {
    m: usize,
    n: usize,
    k: usize,
    precision: Precision,
    ccp: Ccp,
    tiles: usize,
    predicted: u64,
    executed: u64,
    macs: u64,
    lower_ns: u64,
    wall_ns: u64,
    pack_wall_ns: u64,
    step_bytes: u64,
    footprints: String,
}

/// Host wall-time of the plan's serial pack schedule alone: replay the
/// step stream, executing only the `Pack` steps. This is the numerator
/// the parallel-pack slices attack; recorded per case as
/// `pack_wall_ns`.
fn time_pack_walk<T: Element>(spec: &PlanSpec, a: &Mat<T>, b: &Mat<T>) -> u64 {
    let t0 = std::time::Instant::now();
    for step in spec.walk() {
        if let PlanStep::Pack(p) = step {
            match p.buffer {
                Buffer::Ac => {
                    std::hint::black_box(pack_a(a, p.row_off, p.col_off, p.rows, p.cols));
                }
                Buffer::Bc => {
                    std::hint::black_box(pack_b(b, p.row_off, p.col_off, p.rows, p.cols));
                }
            }
        }
    }
    t0.elapsed().as_nanos() as u64
}

fn run_case<T: Element>(
    arch: &versal_gemm::VersalArch,
    m: usize,
    n: usize,
    k: usize,
    ccp: Ccp,
    tiles: usize,
    seed: u64,
) -> Case {
    let prec = T::PRECISION;
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.ccp = ccp;
    let t0 = std::time::Instant::now();
    let plan = GemmPlan::lower(arch, &cfg, m, n, k, prec, false)
        .expect("bench case must lower (feasible by construction)");
    let lower_ns = t0.elapsed().as_nanos() as u64;
    let step_bytes = plan.step_bytes();
    let predicted = plan.cost(arch);

    // --- gate 4: the streaming fold prices the identical schedule -----
    let spec = PlanSpec::new(arch, &cfg, m, n, k, prec, false)
        .expect("spec validates whenever lowering succeeds");
    assert_eq!(
        spec.cost_streaming(arch),
        predicted,
        "GATE: streaming cost must equal materialized cost for ({m}, {n}, {k}) {prec}"
    );

    let mut rng = Pcg32::new(seed);
    let a = Mat::<T>::random(m, k, &mut rng);
    let b = Mat::<T>::random(k, n, &mut rng);
    let mut c = Mat::<T::Acc>::zeros(m, n);
    let engine = ParallelGemm::new(arch);
    let t1 = std::time::Instant::now();
    let (executed, _) = engine.run_p::<T>(&cfg, &a, &b, &mut c).expect("bench case runs");
    let wall_ns = t1.elapsed().as_nanos() as u64;
    let pack_wall_ns = time_pack_walk(&spec, &a, &b);

    // --- gate 1: predicted == executed, bit-for-bit ------------------
    assert_eq!(
        predicted, executed,
        "GATE: plan cost must equal executed cycles for ({m}, {n}, {k}) {prec}"
    );
    // --- gate 2: effective MACs are conserved ------------------------
    assert_eq!(
        plan.total_macs(),
        BlockedGemm::total_macs(m, n, k),
        "GATE: plan MACs must equal m*n*k"
    );
    // --- gate 3: footprints fit (lowering validated; record them) ----
    let footprints = plan
        .footprints()
        .iter()
        .map(|fp| {
            assert!(
                fp.peak_bytes <= fp.budget_bytes(),
                "GATE: {:?} oversubscribed after lowering",
                fp.level
            );
            format!(
                "{{\"level\":\"{}\",\"peak_bytes\":{},\"budget_bytes\":{},\"capacity_bytes\":{},\"utilisation\":{:.6}}}",
                fp.level.cache_analogue(),
                fp.peak_bytes,
                fp.budget_bytes(),
                fp.capacity_bytes,
                fp.utilisation()
            )
        })
        .collect::<Vec<_>>()
        .join(",");

    Case {
        m,
        n,
        k,
        precision: prec,
        ccp,
        tiles,
        predicted: predicted.total,
        executed: executed.total,
        macs: plan.total_macs(),
        lower_ns,
        wall_ns,
        pack_wall_ns,
        step_bytes,
        footprints,
    }
}

/// Gate 5: sequential vs 8-worker pooled engine vs the pooled engine
/// with a pack arena + parallel packing, on one shape — every pooled
/// walk must be bit-identical in C, cycles and stats; wall times are
/// best-of-N and recorded (in full mode on ≥4-thread machines the
/// pool is gated >1.5× over sequential and the arena + pack-parallel
/// path strictly faster than the plain pooled baseline).
struct EngineSpeedup {
    m: usize,
    n: usize,
    k: usize,
    workers: usize,
    rounds: usize,
    seq_wall_ns: u64,
    pool_wall_ns: u64,
    arena_wall_ns: u64,
}

impl EngineSpeedup {
    fn speedup(&self) -> f64 {
        self.seq_wall_ns as f64 / self.pool_wall_ns.max(1) as f64
    }

    /// Arena + pack-parallel wall against the plain pooled baseline —
    /// the host-hot-path win this PR ships.
    fn arena_speedup(&self) -> f64 {
        self.pool_wall_ns as f64 / self.arena_wall_ns.max(1) as f64
    }
}

fn run_engine_speedup(
    arch: &versal_gemm::VersalArch,
    m: usize,
    n: usize,
    k: usize,
    ccp: Ccp,
    tiles: usize,
    seed: u64,
    quick: bool,
) -> EngineSpeedup {
    let workers = 8;
    // Best-of-N damps scheduler noise in the full run; quick mode is a
    // schema smoke and takes single shots.
    let rounds = if quick { 1 } else { 3 };
    let mut cfg = GemmConfig::paper_table2(tiles);
    cfg.ccp = ccp;
    let mut rng = Pcg32::new(seed);
    let a = Mat::<u8>::random(m, k, &mut rng);
    let b = Mat::<u8>::random(k, n, &mut rng);

    // Best wall time over `rounds` cold runs of one engine; returns the
    // last run's full result for the bit-exactness gates.
    let best_of = |engine: &ParallelGemm| {
        let mut best = u64::MAX;
        let mut out = None;
        for _ in 0..rounds {
            let mut c = Mat::<i32>::zeros(m, n);
            let t0 = std::time::Instant::now();
            let (cy, st) = engine.run_p::<u8>(&cfg, &a, &b, &mut c).expect("engine runs");
            best = best.min(t0.elapsed().as_nanos() as u64);
            out = Some((c, cy, st));
        }
        let (c, cy, st) = out.expect("at least one round");
        (c, cy, st, best)
    };

    let seq = ParallelGemm::new(arch);
    let (c_seq, cy_seq, st_seq, seq_wall_ns) = best_of(&seq);

    let pool = Arc::new(ThreadPool::new(workers));
    let pooled = ParallelGemm::new(arch).with_pool(Arc::clone(&pool));
    let (c_pool, cy_pool, st_pool, pool_wall_ns) = best_of(&pooled);

    // The host hot path: same pool, plus recycled pack buffers and
    // slice-parallel packing. The arena starts cold — its first run
    // pays the fresh checkouts, later rounds run warm, exactly the
    // serving steady state best-of-N is meant to sample.
    let hot = ParallelGemm::new(arch)
        .with_pool(pool)
        .with_arena(Arc::new(PackArena::new()))
        .with_pack_parallel(true);
    let (c_hot, cy_hot, st_hot, arena_wall_ns) = best_of(&hot);

    // The deterministic-reduction invariant, asserted where the perf
    // number is produced: a speedup that changes bits is no speedup.
    assert_eq!(
        c_seq.data, c_pool.data,
        "GATE: pooled engine must be bit-identical to sequential on ({m}, {n}, {k})"
    );
    assert_eq!(cy_seq, cy_pool, "GATE: pooled cycle accounting must match sequential");
    assert_eq!(st_seq, st_pool, "GATE: pooled tile stats must match sequential");
    assert_eq!(
        c_seq.data, c_hot.data,
        "GATE: arena + pack-parallel engine must be bit-identical to sequential on ({m}, {n}, {k})"
    );
    assert_eq!(cy_seq, cy_hot, "GATE: arena + pack-parallel cycle accounting must match");
    assert_eq!(st_seq, st_hot, "GATE: arena + pack-parallel tile stats must match");

    EngineSpeedup { m, n, k, workers, rounds, seq_wall_ns, pool_wall_ns, arena_wall_ns }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let arch = vc1902();

    println!("=== plan IR: predicted vs executed schedule parity ===");
    println!("(every row asserts plan.cost == ParallelGemm cycles bit-for-bit{})\n",
        if quick { " [quick]" } else { "" });

    let small = Ccp { mc: 32, nc: 32, kc: 64 };
    let mut cases = vec![
        run_case::<u8>(&arch, 96, 80, 160, small, 4, 0xB1),
        run_case::<i8>(&arch, 63, 49, 97, small, 3, 0xB2),
        run_case::<i16>(&arch, 48, 40, 80, small, 2, 0xB3),
        run_case::<Bf16>(&arch, 40, 33, 65, small, 2, 0xB4),
    ];
    if !quick {
        // The paper's Table-2 problem, at the paper's CCP.
        cases.push(run_case::<u8>(
            &arch,
            256,
            256,
            2048,
            Ccp { mc: 256, nc: 256, kc: 2048 },
            8,
            0xB5,
        ));
    }

    println!(
        "{:<28} {:>6} {:>14} {:>14} {:>12} {:>12} {:>12} {:>10} {:>12}",
        "case", "tiles", "predicted", "executed", "MACs/cycle", "lower µs", "wall µs", "pack µs",
        "step bytes"
    );
    for c in &cases {
        println!(
            "{:<28} {:>6} {:>14} {:>14} {:>12.1} {:>12.1} {:>12.1} {:>10.1} {:>12}",
            format!("({}, {}, {}) {}", c.m, c.n, c.k, c.precision),
            c.tiles,
            c.predicted,
            c.executed,
            c.macs as f64 / c.executed as f64,
            c.lower_ns as f64 / 1e3,
            c.wall_ns as f64 / 1e3,
            c.pack_wall_ns as f64 / 1e3,
            c.step_bytes,
        );
    }

    // --- gate 5: cross-engine bit-exactness + wall-time speedup -------
    // Quick mode keeps the block (and the bit-exactness gate) on a
    // smaller shape so the JSON schema is identical; the >1.5× wall
    // gate only arms on the full run's Table-2 shape, and only when
    // the machine has the hardware threads to make it meaningful.
    let sp = if quick {
        run_engine_speedup(&arch, 96, 80, 160, small, 4, 0xE5, quick)
    } else {
        run_engine_speedup(
            &arch,
            256,
            256,
            2048,
            Ccp { mc: 256, nc: 256, kc: 2048 },
            8,
            0xE5,
            quick,
        )
    };
    let hw_threads = std::thread::available_parallelism().map_or(1, |p| p.get());
    println!(
        "\nengine speedup ({}, {}, {}): sequential {:.2} ms, {}-worker pool {:.2} ms \
         — {:.2}x; + arena & parallel packing {:.2} ms — {:.2}x over the plain pool \
         (best of {}, bit-identical C, cycles, stats)",
        sp.m,
        sp.n,
        sp.k,
        sp.seq_wall_ns as f64 / 1e6,
        sp.workers,
        sp.pool_wall_ns as f64 / 1e6,
        sp.speedup(),
        sp.arena_wall_ns as f64 / 1e6,
        sp.arena_speedup(),
        sp.rounds
    );
    if !quick && hw_threads >= 4 {
        assert!(
            sp.speedup() > 1.5,
            "GATE: {}-worker pool must beat sequential by >1.5x on the Table-2 shape \
             (got {:.2}x on a {hw_threads}-thread host)",
            sp.workers,
            sp.speedup()
        );
        assert!(
            sp.arena_speedup() > 1.0,
            "GATE: arena + parallel packing must be strictly faster than the plain \
             {}-worker pool on the Table-2 shape (got {:.2}x on a {hw_threads}-thread host)",
            sp.workers,
            sp.arena_speedup()
        );
    }

    // --- machine-readable artifact: BENCH_plan.json ------------------
    let json_cases = cases
        .iter()
        .map(|c| {
            format!(
                "{{\"m\":{},\"n\":{},\"k\":{},\"precision\":\"{}\",\"mc\":{},\"nc\":{},\"kc\":{},\
                 \"tiles\":{},\"predicted_cycles\":{},\"executed_cycles\":{},\"macs\":{},\
                 \"macs_per_cycle\":{:.4},\"lower_ns\":{},\"wall_ns\":{},\"pack_wall_ns\":{},\
                 \"step_bytes\":{},\"footprints\":[{}]}}",
                c.m,
                c.n,
                c.k,
                c.precision,
                c.ccp.mc,
                c.ccp.nc,
                c.ccp.kc,
                c.tiles,
                c.predicted,
                c.executed,
                c.macs,
                c.macs as f64 / c.executed as f64,
                c.lower_ns,
                c.wall_ns,
                c.pack_wall_ns,
                c.step_bytes,
                c.footprints
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    // Wall-time fields deliberately do not end in "cycles": bench-trend
    // gates the cycle domain only, and host wall time is machine-noise.
    let json = format!(
        "{{\"bench\":\"plan\",\"schema\":\"plan-v3\",\"quick\":{quick},\"parity\":\"exact\",\
         \"engine_speedup\":{{\"m\":{},\"n\":{},\"k\":{},\"workers\":{},\"rounds\":{},\
         \"seq_wall_ns\":{},\"pool_wall_ns\":{},\"arena_wall_ns\":{},\"speedup\":{:.4},\
         \"arena_speedup\":{:.4},\"bit_exact\":true}},\
         \"cases\":[{json_cases}]}}\n",
        sp.m,
        sp.n,
        sp.k,
        sp.workers,
        sp.rounds,
        sp.seq_wall_ns,
        sp.pool_wall_ns,
        sp.arena_wall_ns,
        sp.speedup(),
        sp.arena_speedup()
    );
    let dir = std::path::PathBuf::from(
        std::env::var_os("VERSAL_BENCH_RESULTS").unwrap_or_else(|| "bench_results".into()),
    );
    std::fs::create_dir_all(&dir).expect("create bench results dir");
    let path = dir.join("BENCH_plan.json");
    std::fs::write(&path, &json).expect("write BENCH_plan.json");
    println!("\nwrote {}", path.display());
    println!(
        "all plan gates passed (predicted == executed, streaming == materialized, \
         pooled / arena / pack-parallel engines bit-identical on every case)."
    );
}
