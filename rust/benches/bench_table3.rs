//! Bench: regenerate **Table 3** of the paper — ablated micro-kernel
//! cycle counts (read-Ar-only / mac16-only / baseline) against the
//! theoretical calculations, plus the §5.3 overlap analysis.
//!
//! ```bash
//! cargo bench --bench bench_table3
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::report;
use versal_gemm::sim::{AieTileModel, KernelMode};

fn main() {
    let arch = vc1902();
    println!("=== Table 3 (kc = 2048, cycles) ===\n");
    println!("{}", report::table3(&arch).to_text());

    let m = AieTileModel::new(&arch);
    let read = m.kernel_cycles(2048, KernelMode::ReadArOnly, false).total;
    let mac = m.kernel_cycles(2048, KernelMode::MacOnly, false).total;
    let base = m.kernel_cycles(2048, KernelMode::Baseline, false).total;

    println!("=== §5.3 overlap analysis ===\n");
    println!("components measured separately: read {read} + mac {mac} = {}", read + mac);
    println!("combined kernel measured:       {base}");
    println!(
        "⇒ overlap hides {} cycles — the combined cost matches the heavier \
         component (paper: \"perfect overlap\")\n",
        read + mac - base
    );
    println!(
        "naive rate estimate (unfused 38-cycle reads, no overlap): {:.1} MACs/cycle",
        m.naive_macs_per_cycle_estimate()
    );
    println!(
        "achieved single-tile rate: {:.1} MACs/cycle of a {} peak \
         ⇒ communication-bound on the Ultra RAM stream",
        131072.0 / (base + 40) as f64,
        arch.peak_macs_per_cycle()
    );
    println!(
        "compute-to-communication ratio: {:.0} MACs per Ar byte (paper: 8)",
        m.macs_per_ar_byte()
    );

    // kc sensitivity of the three rows (extension beyond the paper's
    // single kc): the fusion saving and the overlap margin vs kc.
    println!("\n=== kc sweep (extension) ===\n");
    let mut t = versal_gemm::util::tabulate::Table::new(&[
        "kc", "read ar", "mac16", "baseline", "theory baseline", "overlap saved",
    ]);
    for kc in [256usize, 512, 1024, 2048, 3744] {
        let r = m.kernel_cycles(kc, KernelMode::ReadArOnly, false).total;
        let a = m.kernel_cycles(kc, KernelMode::MacOnly, false).total;
        let b = m.kernel_cycles(kc, KernelMode::Baseline, false).total;
        let th = m.kernel_cycles_theoretical(kc, KernelMode::Baseline);
        t.row(&[kc.to_string(), r.to_string(), a.to_string(), b.to_string(), th.to_string(), (r + a - b).to_string()]);
    }
    println!("{}", t.to_text());
}
