//! Bench: **continuous-batching serving** — the fused-batch +
//! packed-operand-cache runtime against sequential uncached dispatch on
//! the paper's Table-2 GEMM shape, plus the lowered-plan cache against
//! the re-lower-per-batch baseline.
//!
//! Acceptance gates (asserted, not just printed):
//!
//! 1. batched-with-cache throughput **strictly beats** sequential
//!    uncached dispatch (per-request pipelined cycles vs per-request
//!    strictly-serialised cycles) on the Table-2 problem;
//! 2. packed-cache **hits are bit-exact** with cold-pack results: a
//!    warm replay of the identical wave returns identical logits;
//! 3. the plan-cache warm path is **strictly cheaper** than the
//!    re-lower-per-batch path: identical logits and identical simulated
//!    cycles (the cache is a host-side optimisation and must not move
//!    the cycle domain), with strictly fewer plans lowered — the
//!    repeated Table-2 shape lowers once, not once per batch;
//! 4. the multi-tenant goodput-vs-offered-load sweep degrades
//!    **gracefully**: near-unity goodput under light load, a collapsed
//!    goodput fraction far past the saturation knee, shedding ordered
//!    lowest-priority-first, and the gold tenant's p99 within its SLO
//!    even at 16x the calibrated capacity;
//! 5. cross-batch **fan-out is byte-invisible**: replaying one
//!    multi-tenant trace with distinct-tenant batches executing
//!    concurrently on a host pool produces a report fingerprint
//!    byte-identical to the sequential tick (both walls recorded in
//!    the `fanout` block, never gated — host wall is machine-noise).
//!
//! The runtime is deterministic (logical clock + calibrated cycle
//! models), so these gates are CI-stable; host *wall-time* (`wall_ns`
//! per mode, plus the lowering `plan_lower_ns`) is reported in
//! `BENCH_serving.json` but gated on the deterministic counts only.
//!
//! `--quick` runs a **one-point** goodput sweep (the light-load point)
//! instead of the five-point overload curve, so the `goodput_sweep`
//! block — and the whole JSON schema — is identical between quick and
//! full runs; the overload-shape gates (collapse, shed ordering, gold
//! p99) only arm on the full sweep, which is the only run that drives
//! past the knee.
//!
//! ```bash
//! cargo bench --bench bench_serving            # full (wave = 256 rows)
//! cargo bench --bench bench_serving -- --quick # CI smoke (wave = 32)
//! ```

use std::sync::Arc;
use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    generate, ArrivalKind, FeatureGen, RustGemmBackend, ServingConfig, ServingReport,
    ServingRuntime, TenantClass, WorkloadSpec,
};
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::Precision;
use versal_gemm::report;
use versal_gemm::runtime::ThreadPool;

#[allow(clippy::too_many_arguments)]
fn runtime(
    spec: &MlpSpec,
    tiles: usize,
    max_batch: usize,
    cache_bytes: u64,
    plan_cache_bytes: u64,
    devices: usize,
    queue_cap: usize,
) -> ServingRuntime<RustGemmBackend> {
    let backend = RustGemmBackend::new(vc1902(), spec.clone(), 9, tiles);
    ServingRuntime::new(
        backend,
        ServingConfig {
            max_batch,
            max_wait_us: 0,
            queue_cap,
            default_slo_us: 1 << 40,
            cache_budget_bytes: cache_bytes,
            plan_cache_budget_bytes: plan_cache_bytes,
            pipeline_devices: devices,
            max_backlog_us: u64::MAX,
        },
    )
}

/// One point of the goodput-vs-offered-load sweep.
struct SweepPoint {
    load_x: f64,
    offered_rps: f64,
    submitted: u64,
    completed: u64,
    completed_in_slo: u64,
    shed: u64,
    goodput_frac: f64,
    gold_p99_us: f64,
    gold_slo_us: u64,
    shed_rates: [f64; 3], // gold, silver, free
}

/// Goodput-vs-offered-load sweep: a gold/silver/free tenant mix driven
/// at multiples of the runtime's calibrated capacity through priority
/// admission control. Returns the sweep points plus the knee (the last
/// load multiplier whose aggregate goodput fraction stays ≥ 0.85).
fn goodput_sweep(spec: &MlpSpec, tiles: usize, quick: bool) -> (Vec<SweepPoint>, f64) {
    // Calibrate the per-row service time from one full batch on a
    // scratch runtime: at the 1 GHz model clock a simulated cycle is a
    // nanosecond, so capacity (rows/second, batch-amortised) falls
    // straight out of the pipelined makespan.
    let max_batch = 16;
    let mut scratch = runtime(spec, tiles, max_batch, 256 << 20, 8 << 20, 2, 4 * max_batch);
    let mut gen = FeatureGen::new(spec.dims[0], 7);
    for _ in 0..max_batch {
        scratch.submit(gen.next(), Precision::U8, 0).expect("admit");
    }
    scratch.drain(0);
    let cal = scratch.report();
    let per_row_cycles = cal.pipelined_cycles as f64 / cal.completed as f64;
    let per_row_us = per_row_cycles / 1_000.0;
    let capacity_rps = 1e9 / per_row_cycles;

    let max_wait_us = 500;
    let max_backlog_us = 2_000;
    // The gold SLO covers forming wait + the bounded backlog + one
    // batch of service with 4x slack; silver and free relax it.
    let gold_slo_us = (4.0 * (max_wait_us as f64 + max_backlog_us as f64
        + max_batch as f64 * per_row_us)) as u64;
    let classes = vec![
        TenantClass::new("gold", 1.0, 3, gold_slo_us),
        TenantClass::new("silver", 8.0, 2, 4 * gold_slo_us),
        TenantClass::new("free", 23.0, 1, 16 * gold_slo_us),
    ];

    // Quick keeps only the light-load point: the sweep block (and the
    // JSON schema) stays identical, while the overload points — the
    // expensive ones — run in full mode only.
    let loads: &[f64] = if quick { &[0.05] } else { &[0.05, 0.25, 1.0, 4.0, 16.0] };
    let requests = if quick { 256 } else { 768 };
    let mut points = Vec::new();
    for &load_x in loads {
        let offered_rps = load_x * capacity_rps;
        let backend = RustGemmBackend::new(vc1902(), spec.clone(), 9, tiles);
        let mut rt = ServingRuntime::with_tenants(
            backend,
            ServingConfig {
                max_batch,
                max_wait_us,
                queue_cap: 256,
                default_slo_us: gold_slo_us,
                cache_budget_bytes: 256 << 20,
                plan_cache_budget_bytes: 8 << 20,
                pipeline_devices: 2,
                max_backlog_us,
            },
            classes.clone(),
        );
        let trace = generate(
            &WorkloadSpec {
                tenants: classes.clone(),
                kind: ArrivalKind::Poisson,
                offered_rate: offered_rps,
                burst: 1.0,
                requests,
                seed: 1717,
            },
            spec.dims[0],
        );
        rt.replay(&trace);
        let rep = rt.report();
        let submitted: u64 = rep.tenants.iter().map(|t| t.submitted).sum();
        let in_slo: u64 = rep.tenants.iter().map(|t| t.completed_in_slo).sum();
        let shed: u64 = rep.tenants.iter().map(|t| t.shed).sum();
        points.push(SweepPoint {
            load_x,
            offered_rps,
            submitted,
            completed: rep.completed,
            completed_in_slo: in_slo,
            shed,
            goodput_frac: if submitted == 0 { 0.0 } else { in_slo as f64 / submitted as f64 },
            gold_p99_us: rep.tenants[0].latency.as_ref().map(|l| l.p99_us).unwrap_or(0.0),
            gold_slo_us,
            shed_rates: [
                rep.tenants[0].shed_rate(),
                rep.tenants[1].shed_rate(),
                rep.tenants[2].shed_rate(),
            ],
        });
    }
    let knee = points
        .iter()
        .filter(|p| p.goodput_frac >= 0.85)
        .map(|p| p.load_x)
        .fold(loads[0], f64::max);
    (points, knee)
}

/// Gate 5: replay one multi-tenant trace with fan-out off and with
/// distinct-tenant batches fanned out across a 4-worker host pool.
/// The report fingerprint must be **byte-identical** — fan-out is a
/// host-side latency optimisation and may not move a single counter —
/// and both host walls are recorded in the JSON `fanout` block.
fn fanout_compare(spec: &MlpSpec, tiles: usize, quick: bool) -> (u64, u64, u64) {
    let classes = vec![
        TenantClass::new("gold", 1.0, 3, 1 << 40),
        TenantClass::new("silver", 2.0, 2, 1 << 40),
        TenantClass::new("free", 3.0, 1, 1 << 40),
    ];
    let requests = if quick { 64 } else { 256 };
    let trace = generate(
        &WorkloadSpec {
            tenants: classes.clone(),
            kind: ArrivalKind::Poisson,
            offered_rate: 50_000.0,
            burst: 1.0,
            requests,
            seed: 4242,
        },
        spec.dims[0],
    );
    let run = |fanout_workers: Option<usize>| -> (String, u64, u64) {
        let backend = RustGemmBackend::new(vc1902(), spec.clone(), 9, tiles);
        let mut rt = ServingRuntime::with_tenants(
            backend,
            ServingConfig {
                max_batch: 8,
                max_wait_us: 0,
                queue_cap: 4 * requests,
                default_slo_us: 1 << 40,
                cache_budget_bytes: 256 << 20,
                plan_cache_budget_bytes: 8 << 20,
                pipeline_devices: 2,
                max_backlog_us: u64::MAX,
            },
            classes.clone(),
        );
        if let Some(w) = fanout_workers {
            rt = rt.with_fanout(Arc::new(ThreadPool::new(w)));
        }
        let t0 = std::time::Instant::now();
        let (outcomes, _) = rt.replay(&trace);
        let wall_ns = t0.elapsed().as_nanos() as u64;
        (rt.fingerprint(), wall_ns, outcomes.len() as u64)
    };
    let (fp_seq, seq_wall_ns, done_seq) = run(None);
    let (fp_fan, fanout_wall_ns, done_fan) = run(Some(4));
    assert_eq!(done_seq, done_fan, "both replays complete the same requests");
    assert!(done_seq > 0, "the fan-out trace must actually serve requests");
    assert_eq!(
        fp_seq, fp_fan,
        "GATE: cross-batch fan-out must leave the report fingerprint byte-identical"
    );
    (seq_wall_ns, fanout_wall_ns, done_seq)
}

/// Drive two identical waves through a runtime; returns the outcomes'
/// logits per wave plus the final report and the host wall time of
/// the whole replay (first-class next to the simulated cycles).
fn two_waves(
    rt: &mut ServingRuntime<RustGemmBackend>,
    wave_features: &[Vec<f32>],
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, ServingReport, u64) {
    let t0 = std::time::Instant::now();
    let mut serve_wave = |now: u64| -> Vec<Vec<f32>> {
        for f in wave_features {
            rt.submit(f.clone(), Precision::U8, now).expect("admit");
        }
        rt.drain(now).into_iter().map(|o| o.logits).collect()
    };
    let w1 = serve_wave(0);
    let w2 = serve_wave(1_000);
    let wall_ns = t0.elapsed().as_nanos() as u64;
    (w1, w2, rt.report(), wall_ns)
}

fn json_row(label: &str, r: &ServingReport, wall_ns: u64) -> String {
    // The flat fields are the historical trend surface (what
    // `versal-gemm bench-trend` diffs against older artifacts); the
    // nested "metrics" object is the full unified registry snapshot —
    // the same one `serve --trace-out` prints — so new metrics join the
    // artifact without another hand-rolled field list.
    format!(
        "{{\"mode\":\"{label}\",\"completed\":{},\"batches\":{},\
         \"pack_cycles\":{},\"transfer_cycles\":{},\"compute_cycles\":{},\
         \"pipelined_cycles\":{},\"sequential_cycles\":{},\
         \"cache_hits\":{},\"cache_misses\":{},\
         \"plan_cache_hits\":{},\"plan_cache_misses\":{},\
         \"plans_lowered\":{},\"plan_lower_ns\":{},\"wall_ns\":{wall_ns},\"metrics\":{}}}",
        r.completed,
        r.batches,
        r.pack_cycles,
        r.transfer_cycles,
        r.compute_cycles,
        r.pipelined_cycles,
        r.sequential_cycles,
        r.cache.hits,
        r.cache.misses,
        r.plan_cache.hits,
        r.plan_cache.misses,
        r.plan_cache.lowered,
        r.plan_cache.lower_ns,
        r.metrics().to_json(),
    )
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let wave = if quick { 32 } else { 256 };
    let tiles = 8;
    // One linear layer with the Table-2 k and n: a fused wave of `wave`
    // single-row requests is exactly the (wave, 2048) · (2048, 256) GEMM
    // — at wave = 256, the paper's Table-2 problem.
    let spec = MlpSpec { dims: vec![2048, 256] };
    let in_dim = spec.dims[0];

    println!("=== continuous-batching serving: fused + packed cache vs sequential uncached ===");
    println!(
        "(single-layer MLP {in_dim}→256 on {tiles} tiles; fused wave = ({wave}, 2048)·(2048, 256){})\n",
        if quick { " [quick]" } else { "" }
    );

    // The same trace drives every runtime: two identical waves.
    let mut gen = FeatureGen::new(in_dim, 42);
    let wave_features: Vec<Vec<f32>> = (0..wave).map(|_| gen.next()).collect();

    // --- A: continuous batching, packed + plan caches on --------------
    let mut batched = runtime(&spec, tiles, wave, 256 << 20, 8 << 20, 2, 4 * wave);
    let (wave1, wave2, rep_a, wall_a) = two_waves(&mut batched, &wave_features);
    assert_eq!(wave1.len(), wave);
    assert_eq!(wave2.len(), wave);
    for (a, b) in wave1.iter().zip(&wave2) {
        assert_eq!(
            a, b,
            "GATE: packed-cache hit must be bit-exact with the cold pack"
        );
    }
    assert!(rep_a.cache.hits > 0, "warm wave must hit the cache");
    assert_eq!(rep_a.expired, 0);

    // --- B: sequential uncached dispatch of the identical trace ------
    let mut sequential = runtime(&spec, tiles, 1, 0, 0, 1, 4 * wave);
    let (_, _, rep_b, wall_b) = two_waves(&mut sequential, &wave_features);
    assert_eq!(rep_b.completed, rep_a.completed, "same request count both sides");
    assert_eq!(rep_b.cache.hits, 0, "budget 0 ⇒ nothing is ever resident");

    // --- C: caches as in A, but the plan cache off (re-lower/batch) --
    let mut relower = runtime(&spec, tiles, wave, 256 << 20, 0, 2, 4 * wave);
    let (wave1_c, wave2_c, rep_c, wall_c) = two_waves(&mut relower, &wave_features);

    println!("batched + cached (pipelined makespan, host wall {:.2} ms):", wall_a as f64 / 1e6);
    println!("{}", report::serving_table(&rep_a).to_text());
    println!("sequential uncached (serialised makespan, host wall {:.2} ms):", wall_b as f64 / 1e6);
    println!("{}", report::serving_table(&rep_b).to_text());
    println!(
        "batched + cached, plan cache OFF (re-lower per batch, host wall {:.2} ms):",
        wall_c as f64 / 1e6
    );
    println!("{}", report::serving_table(&rep_c).to_text());

    // --- the throughput gate -----------------------------------------
    let per_req_batched = rep_a.pipelined_cycles as f64 / rep_a.completed as f64;
    let per_req_seq = rep_b.sequential_cycles as f64 / rep_b.completed as f64;
    let speedup = per_req_seq / per_req_batched;
    println!(
        "per-request cycles: batched+cached {per_req_batched:.0} vs sequential uncached \
         {per_req_seq:.0}  ⇒  {speedup:.1}x"
    );
    assert!(
        per_req_batched < per_req_seq,
        "GATE: batched-with-cache must strictly beat sequential uncached dispatch \
         ({per_req_batched:.0} !< {per_req_seq:.0})"
    );
    // The win must come from both levers: the warm wave skipped the
    // weight pack (cache) and the fused wave amortised the per-batch
    // overheads (batching) — sanity-check the cache half explicitly.
    assert!(
        rep_a.pack_cycles < rep_b.pack_cycles,
        "cached runtime must pack fewer bytes: {} !< {}",
        rep_a.pack_cycles,
        rep_b.pack_cycles
    );

    // --- the plan-cache gate -----------------------------------------
    assert_eq!(wave1, wave1_c, "plan cache must not change numerics (cold)");
    assert_eq!(wave2, wave2_c, "plan cache must not change numerics (warm)");
    assert_eq!(
        rep_a.pipelined_cycles, rep_c.pipelined_cycles,
        "plan cache is host-side only: identical simulated makespan"
    );
    assert!(
        rep_a.plan_cache.lowered < rep_c.plan_cache.lowered,
        "GATE: plan-cache warm path must lower strictly fewer plans than the \
         re-lower-per-batch path: {} !< {}",
        rep_a.plan_cache.lowered,
        rep_c.plan_cache.lowered
    );
    assert_eq!(
        rep_a.plan_cache.lowered, 1,
        "the repeated Table-2 shape lowers exactly once with the cache on"
    );
    assert!(rep_a.plan_cache.hits > 0, "warm wave reuses the resident plan");
    assert_eq!(rep_c.plan_cache.hits, 0, "budget 0 ⇒ no plan is ever resident");
    println!(
        "plan lowering: cache-on {} plans / {:.2} ms vs re-lower-per-batch {} plans / {:.2} ms",
        rep_a.plan_cache.lowered,
        rep_a.plan_cache.lower_ns as f64 / 1e6,
        rep_c.plan_cache.lowered,
        rep_c.plan_cache.lower_ns as f64 / 1e6,
    );

    // --- D: goodput vs offered load (multi-tenant overload) ----------
    let (sweep, knee) = goodput_sweep(&spec, tiles, quick);
    println!("\ngoodput vs offered load (gold:silver:free = 1:8:23 by weight):");
    println!("  load   offered/s   submitted  in-SLO  shed   goodput%   gold p99 µs  shed% g/s/f");
    for p in &sweep {
        println!(
            "  {:>5.2}x {:>10.0}  {:>9}  {:>6}  {:>5}  {:>7.1}%  {:>11.0}  {:.0}/{:.0}/{:.0}",
            p.load_x,
            p.offered_rps,
            p.submitted,
            p.completed_in_slo,
            p.shed,
            p.goodput_frac * 100.0,
            p.gold_p99_us,
            p.shed_rates[0] * 100.0,
            p.shed_rates[1] * 100.0,
            p.shed_rates[2] * 100.0,
        );
    }
    println!("  saturation knee: {knee}x calibrated capacity");

    // --- the overload gates -------------------------------------------
    // The light-load gate holds on both sweeps; the overload-shape
    // gates need the points past the knee, which only the full sweep
    // drives (quick's one-point sweep is the light-load point).
    let first = sweep.first().expect("sweep is non-empty");
    let last = sweep.last().expect("sweep is non-empty");
    assert!(
        first.goodput_frac >= 0.85,
        "GATE: under light load ({}x) nearly all traffic must be goodput: {:.3}",
        first.load_x,
        first.goodput_frac
    );
    if !quick {
        assert!(
            last.goodput_frac <= 0.5,
            "GATE: far past the knee ({}x) the goodput fraction must collapse: {:.3}",
            last.load_x,
            last.goodput_frac
        );
        assert!(
            last.shed_rates[0] <= last.shed_rates[1] && last.shed_rates[1] <= last.shed_rates[2],
            "GATE: shedding must hit the lowest priority hardest: gold {:.3} silver {:.3} free {:.3}",
            last.shed_rates[0],
            last.shed_rates[1],
            last.shed_rates[2]
        );
        assert!(
            last.shed_rates[2] > 0.0,
            "GATE: overload at {}x must shed free-tier traffic",
            last.load_x
        );
        assert!(
            last.gold_p99_us <= last.gold_slo_us as f64,
            "GATE: graceful degradation — gold p99 {:.0} µs must stay within its {} µs SLO \
             even at {}x load",
            last.gold_p99_us,
            last.gold_slo_us,
            last.load_x
        );
    }

    // --- E: cross-batch fan-out parity + wall -------------------------
    let (fanout_seq_wall_ns, fanout_wall_ns, fanout_completed) =
        fanout_compare(&spec, tiles, quick);
    println!(
        "\nfan-out replay ({fanout_completed} requests, 3 tenants): sequential tick \
         {:.2} ms, 4-worker fan-out {:.2} ms (fingerprints byte-identical)",
        fanout_seq_wall_ns as f64 / 1e6,
        fanout_wall_ns as f64 / 1e6
    );

    // --- machine-readable artifact: BENCH_serving.json ----------------
    let sweep_rows: Vec<String> = sweep
        .iter()
        .map(|p| {
            format!(
                "{{\"load_x\":{},\"offered_rps\":{:.0},\"submitted\":{},\
                 \"completed\":{},\"completed_in_slo\":{},\"shed\":{},\
                 \"goodput_frac\":{:.4},\"gold_p99_us\":{:.1},\
                 \"gold_shed_rate\":{:.4},\"silver_shed_rate\":{:.4},\
                 \"free_shed_rate\":{:.4}}}",
                p.load_x,
                p.offered_rps,
                p.submitted,
                p.completed,
                p.completed_in_slo,
                p.shed,
                p.goodput_frac,
                p.gold_p99_us,
                p.shed_rates[0],
                p.shed_rates[1],
                p.shed_rates[2],
            )
        })
        .collect();
    // Wall-time fields end in "_ns", never "cycles": bench-trend gates
    // the cycle domain only, and host wall time is machine-noise.
    let json = format!(
        "{{\"bench\":\"serving\",\"schema\":\"serving-v4\",\"quick\":{quick},\
         \"wave_rows\":{wave},\"rows\":[{},{},{}],\
         \"goodput_sweep\":{{\"knee_load\":{knee},\"points\":[{}]}},\
         \"fanout\":{{\"workers\":4,\"completed\":{fanout_completed},\
         \"seq_wall_ns\":{fanout_seq_wall_ns},\"fanout_wall_ns\":{fanout_wall_ns},\
         \"fingerprint_identical\":true}}}}\n",
        json_row("batched_cached_plan_cache_on", &rep_a, wall_a),
        json_row("sequential_uncached", &rep_b, wall_b),
        json_row("batched_cached_plan_cache_off", &rep_c, wall_c),
        sweep_rows.join(","),
    );
    let dir = std::path::PathBuf::from(
        std::env::var_os("VERSAL_BENCH_RESULTS").unwrap_or_else(|| "bench_results".into()),
    );
    std::fs::create_dir_all(&dir).expect("create bench results dir");
    let path = dir.join("BENCH_serving.json");
    std::fs::write(&path, &json).expect("write BENCH_serving.json");
    println!("\nwrote {}", path.display());
    println!("all serving gates passed.");
}
