//! Bench: **continuous-batching serving** — the fused-batch +
//! packed-operand-cache runtime against sequential uncached dispatch on
//! the paper's Table-2 GEMM shape.
//!
//! Acceptance gates (asserted, not just printed):
//!
//! 1. batched-with-cache throughput **strictly beats** sequential
//!    uncached dispatch (per-request pipelined cycles vs per-request
//!    strictly-serialised cycles) on the Table-2 problem;
//! 2. packed-cache **hits are bit-exact** with cold-pack results: a
//!    warm replay of the identical wave returns identical logits.
//!
//! The runtime is deterministic (logical clock + calibrated cycle
//! models), so these gates are CI-stable.
//!
//! ```bash
//! cargo bench --bench bench_serving            # full (wave = 256 rows)
//! cargo bench --bench bench_serving -- --quick # CI smoke (wave = 32)
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    FeatureGen, RustGemmBackend, ServingConfig, ServingRuntime,
};
use versal_gemm::dl::MlpSpec;
use versal_gemm::gemm::Precision;
use versal_gemm::report;

fn runtime(
    spec: &MlpSpec,
    tiles: usize,
    max_batch: usize,
    cache_bytes: u64,
    devices: usize,
    queue_cap: usize,
) -> ServingRuntime<RustGemmBackend> {
    let backend = RustGemmBackend::new(vc1902(), spec.clone(), 9, tiles);
    ServingRuntime::new(
        backend,
        ServingConfig {
            max_batch,
            max_wait_us: 0,
            queue_cap,
            default_slo_us: 1 << 40,
            cache_budget_bytes: cache_bytes,
            pipeline_devices: devices,
        },
    )
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let quick = args.iter().any(|a| a == "--quick")
        || std::env::var("VERSAL_BENCH_FAST").as_deref() == Ok("1");
    let wave = if quick { 32 } else { 256 };
    let tiles = 8;
    // One linear layer with the Table-2 k and n: a fused wave of `wave`
    // single-row requests is exactly the (wave, 2048) · (2048, 256) GEMM
    // — at wave = 256, the paper's Table-2 problem.
    let spec = MlpSpec { dims: vec![2048, 256] };
    let in_dim = spec.dims[0];

    println!("=== continuous-batching serving: fused + packed cache vs sequential uncached ===");
    println!(
        "(single-layer MLP {in_dim}→256 on {tiles} tiles; fused wave = ({wave}, 2048)·(2048, 256){})\n",
        if quick { " [quick]" } else { "" }
    );

    // The same trace drives both runtimes: two identical waves.
    let mut gen = FeatureGen::new(in_dim, 42);
    let wave_features: Vec<Vec<f32>> = (0..wave).map(|_| gen.next()).collect();

    // --- A: continuous batching with the weight-stationary cache -----
    let mut batched = runtime(&spec, tiles, wave, 256 << 20, 2, 4 * wave);
    for f in &wave_features {
        batched.submit(f.clone(), Precision::U8, 0).expect("admit");
    }
    let wave1 = batched.drain(0);
    for f in &wave_features {
        batched.submit(f.clone(), Precision::U8, 1_000).expect("admit");
    }
    let wave2 = batched.drain(1_000);
    assert_eq!(wave1.len(), wave);
    assert_eq!(wave2.len(), wave);
    for (a, b) in wave1.iter().zip(&wave2) {
        assert_eq!(
            a.logits, b.logits,
            "GATE: packed-cache hit must be bit-exact with the cold pack"
        );
    }
    let rep_a = batched.report();
    assert!(rep_a.cache.hits > 0, "warm wave must hit the cache");
    assert_eq!(rep_a.expired, 0);

    // --- B: sequential uncached dispatch of the identical trace ------
    let mut sequential = runtime(&spec, tiles, 1, 0, 1, 4 * wave);
    for now in [0u64, 1_000] {
        for f in &wave_features {
            sequential.submit(f.clone(), Precision::U8, now).expect("admit");
        }
        sequential.drain(now);
    }
    let rep_b = sequential.report();
    assert_eq!(rep_b.completed, rep_a.completed, "same request count both sides");
    assert_eq!(rep_b.cache.hits, 0, "budget 0 ⇒ nothing is ever resident");

    println!("batched + cached (pipelined makespan):");
    println!("{}", report::serving_table(&rep_a).to_text());
    println!("sequential uncached (serialised makespan):");
    println!("{}", report::serving_table(&rep_b).to_text());

    // --- the throughput gate -----------------------------------------
    let per_req_batched = rep_a.pipelined_cycles as f64 / rep_a.completed as f64;
    let per_req_seq = rep_b.sequential_cycles as f64 / rep_b.completed as f64;
    let speedup = per_req_seq / per_req_batched;
    println!(
        "per-request cycles: batched+cached {per_req_batched:.0} vs sequential uncached \
         {per_req_seq:.0}  ⇒  {speedup:.1}x"
    );
    assert!(
        per_req_batched < per_req_seq,
        "GATE: batched-with-cache must strictly beat sequential uncached dispatch \
         ({per_req_batched:.0} !< {per_req_seq:.0})"
    );
    // The win must come from both levers: the warm wave skipped the
    // weight pack (cache) and the fused wave amortised the per-batch
    // overheads (batching) — sanity-check the cache half explicitly.
    assert!(
        rep_a.pack_cycles < rep_b.pack_cycles,
        "cached runtime must pack fewer bytes: {} !< {}",
        rep_a.pack_cycles,
        rep_b.pack_cycles
    );
    println!("\nall serving gates passed.");
}
