//! Bench: regenerate **Table 2** of the paper — strong scaling of the
//! parallel GEMM design from 1 to 32 AIE tiles on the fixed problem
//! (m, n, k) = (mc, nc, kc) = (256, 256, 2048).
//!
//! Two parts:
//!  1. the cycle table (simulated platform — the paper's actual metric),
//!     printed next to the published values with per-row deltas;
//!  2. host-side wall-time of the full engine (numerics + schedule), so
//!     the harness also measures *this* implementation's speed.
//!
//! ```bash
//! cargo bench --bench bench_table2
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::{GemmConfig, MatI32, MatU8, ParallelGemm};
use versal_gemm::report;
use versal_gemm::util::benchkit::{bench, BenchCfg};
use versal_gemm::util::Pcg32;

fn main() {
    let arch = vc1902();
    let tiles = [1usize, 2, 4, 8, 16, 32];

    println!("=== Table 2 (simulated cycles, model vs paper) ===\n");
    let t2 = report::table2(&arch, &tiles);
    println!("{}", t2.to_text());
    if let Ok(path) = report::save_csv("table2", &t2) {
        println!("(csv: {})\n", path.display());
    }

    // §5.4 summary row.
    let g = ParallelGemm::new(&arch);
    let r1 = g.table2_row(1);
    let r32 = g.table2_row(32);
    println!(
        "parallel efficiency 1→32 tiles: per-tile perf −{:.1}% (paper −5.7%), speedup {:.1}×\n",
        (1.0 - r32.perf_per_tile / r1.perf_per_tile) * 100.0,
        r1.total_cycles as f64 / r32.total_cycles as f64
    );

    // Host-side timing of the full engine (numerics included).
    println!("=== host wall-time of the Rust engine on the same problem ===\n");
    let cfg_bench = BenchCfg::from_env();
    let mut rng = Pcg32::new(0xB2);
    let a = MatU8::random(256, 2048, &mut rng);
    let b = MatU8::random(2048, 256, &mut rng);
    for &t in &[1usize, 8, 32] {
        let cfg = GemmConfig::paper_table2(t);
        let engine = ParallelGemm::new(&arch);
        let r = bench(&format!("parallel_gemm/256x256x2048/tiles={t}"), &cfg_bench, || {
            let mut c = MatI32::zeros(256, 256);
            engine.run(&cfg, &a, &b, &mut c).unwrap()
        });
        let macs = 256.0 * 256.0 * 2048.0;
        println!(
            "{}   {:.2} GMAC/s host",
            r.human(),
            r.throughput(macs) / 1e9
        );
    }
}
