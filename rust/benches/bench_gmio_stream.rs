//! Bench: the **§4.5 GMIO vs streaming** experiment for the Br transport.
//!
//! The paper's initial design moved Br over GMIO: the ping/pong protocol
//! triples the local-memory footprint (payload + 2 buffers), capping the
//! usable kc, and stalls on window synchronisation. Switching to the
//! streaming interface freed the local memory, allowed a larger kc, and
//! raised the kernel from 30 to 37.4 MACs/cycle.
//!
//! ```bash
//! cargo bench --bench bench_gmio_stream
//! ```

use versal_gemm::arch::{vc1902, MemLevel};
use versal_gemm::gemm::ccp::LOCAL_RESERVED_BYTES;
use versal_gemm::sim::{AieTileModel, Gmio, KernelMode, MemPool, Stream};

/// Sustained MACs/cycle of one tile over an L4 iteration: the micro-kernel
/// loop plus the (possibly stalled) Br transport, amortised over the L5
/// iterations, excluding the Cr transfer common to both designs.
///
/// `steady` models the defining property of the streaming design: the Ar
/// stream never stops across micro-kernels and pipelines at the
/// steady-state rate, whereas GMIO's per-window synchronisation breaks
/// the stream back to isolated-kernel costs.
fn sustained_rate(
    arch: &versal_gemm::VersalArch,
    kc: usize,
    l5_iters: u64,
    br_sync_stall: u64,
    br_copy_exposed: bool,
    steady: bool,
) -> f64 {
    let tile = AieTileModel::new(arch);
    let stream = Stream::new(arch);
    let kernel = tile.kernel_cycles(kc, KernelMode::Baseline, steady).total + br_sync_stall;
    let br_bytes = (kc * 8) as u64;
    let br = if br_copy_exposed { stream.br_copy_cycles(br_bytes) } else { 0 };
    let total = kernel * l5_iters + br;
    let macs = (8 * 8 * kc) as u64 * l5_iters;
    macs as f64 / total as f64
}

fn main() {
    let arch = vc1902();
    let gmio = Gmio::new(&arch);
    let local_cap = arch.mem_capacity(MemLevel::LocalMemory);

    // --- Design 1: GMIO ping/pong. Max payload: 3·payload ≤ local − resv.
    let budget = local_cap - LOCAL_RESERVED_BYTES;
    let gmio_payload = (budget / 3) & !0x7F; // paper dedicates 8 KB
    let gmio_payload = gmio_payload.min(8 * 1024);
    let kc_gmio = (gmio_payload / 8) as usize; // nr = 8, 1 B elements
    // Footprint check through the real allocator.
    let mut pool = MemPool::new(MemLevel::LocalMemory, local_cap);
    gmio.alloc_window(&mut pool, "br", gmio_payload).expect("ping/pong buffers fit");
    println!("=== §4.5 Br transport comparison ===\n");
    println!(
        "GMIO design:      payload {} B ⇒ local footprint {} B (window+ping+pong), kc = {}",
        gmio_payload,
        gmio.local_footprint_bytes(gmio_payload),
        kc_gmio
    );

    // --- Design 2: streaming. Br occupies most of local memory.
    let kc_stream = ((budget / 8) as usize) & !15; // nr=8 bytes/row, 16-align
    println!(
        "streaming design: no buffers ⇒ Br budget {} B, kc = {}\n",
        budget, kc_stream
    );

    // Rates: GMIO pays the window-sync stall each micro-kernel; streaming
    // exposes the Br copy once per L4 iteration (amortised over L5).
    let l5 = 32; // mc/mr for the paper problem
    let gmio_rate = sustained_rate(&arch, kc_gmio, l5, gmio.window_sync_cycles(), false, false);
    let stream_rate = sustained_rate(&arch, kc_stream, l5, 0, true, true);

    let mut t = versal_gemm::util::tabulate::Table::new(&[
        "design", "kc", "local mem for Br", "MACs/cycle (model)", "paper",
    ]);
    t.row(&[
        "GMIO ping/pong".to_string(),
        kc_gmio.to_string(),
        format!("{} B", gmio_payload),
        format!("{gmio_rate:.1}"),
        "30.0".to_string(),
    ]);
    t.row(&[
        "streaming".to_string(),
        kc_stream.to_string(),
        format!("{} B", kc_stream * 8),
        format!("{stream_rate:.1}"),
        "37.4".to_string(),
    ]);
    println!("{}", t.to_text());
    println!(
        "streaming/GMIO ratio: {:.2}× (paper: {:.2}×) — same direction and \
         comparable magnitude; see EXPERIMENTS.md for the residual discussion",
        stream_rate / gmio_rate,
        37.4 / 30.0
    );

    // Compute-to-communication ratio curve (the paper's formula).
    println!("\nkc ⇒ compute-to-comm ratio 2·mr·nr·kc / (2·mr·nr + mr·kc + nr·kc):");
    for kc in [kc_gmio, 2048, kc_stream] {
        let ccp = versal_gemm::gemm::Ccp { mc: 256, nc: 256, kc };
        println!("  kc = {kc:5}: {:.2} MACs/byte", ccp.compute_to_comm_ratio());
    }
}
