//! Bench: **host micro-kernel performance** — the perf-pass harness for
//! the Rust numeric hot path (EXPERIMENTS.md §Perf).
//!
//! Measures the packed 8×8 micro-kernel, the packing routines, and the
//! full engines (sequential reference and the work-stealing thread
//! pool) against the naive and ikj baselines. Each timed row that has
//! a cycle-model counterpart prints the **model cycles next to the
//! host wall time** — the wall numbers are machine-dependent, the
//! model cycles are not (and are identical across host engines).
//!
//! ```bash
//! cargo bench --bench bench_microkernel
//! ```

use std::sync::Arc;
use versal_gemm::arch::vc1902;
use versal_gemm::gemm::baseline::{ikj_gemm, naive_gemm};
use versal_gemm::gemm::{
    pack_a, pack_b, Ccp, GemmConfig, MatI32, MatU8, MicroKernel, ParallelGemm, MR, NR,
};
use versal_gemm::runtime::ThreadPool;
use versal_gemm::sim::{AieTileModel, KernelMode};
use versal_gemm::util::benchkit::{bench, black_box, BenchCfg};
use versal_gemm::util::Pcg32;

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Pcg32::new(0xBE);

    // 1. The micro-kernel itself: 8×8×2048 (the paper's kc).
    let kc = 2048;
    let a = MatU8::random(MR, kc, &mut rng);
    let b = MatU8::random(kc, NR, &mut rng);
    let pa = pack_a(&a, 0, 0, MR, kc);
    let pb = pack_b(&b, 0, 0, kc, NR);
    let r = bench("microkernel/8x8xkc2048", &cfg, || {
        let mut cr = [0i32; MR * NR];
        MicroKernel.run(kc, pa.panel(0), pb.panel(0), &mut cr);
        black_box(cr)
    });
    let macs = (MR * NR * kc) as f64;
    // The AIE model's cycle count for the same invocation — the
    // machine-independent column next to the host wall time.
    let arch = vc1902();
    let model_cycles = AieTileModel::new(&arch)
        .kernel_cycles(kc, KernelMode::Baseline, false)
        .total;
    println!(
        "{}   {:.2} GMAC/s   [model: {model_cycles} AIE cycles]",
        r.human(),
        r.throughput(macs) / 1e9
    );

    // 2. Packing routines.
    let big = MatU8::random(256, 2048, &mut rng);
    let r = bench("pack_a/256x2048", &cfg, || black_box(pack_a(&big, 0, 0, 256, 2048)));
    println!("{}   {:.2} GB/s", r.human(), r.throughput(256.0 * 2048.0) / 1e9);
    let bigb = MatU8::random(2048, 256, &mut rng);
    let r = bench("pack_b/2048x256", &cfg, || black_box(pack_b(&bigb, 0, 0, 2048, 256)));
    println!("{}   {:.2} GB/s", r.human(), r.throughput(2048.0 * 256.0) / 1e9);

    // 3. Full engines on a mid-size problem, vs baselines.
    let (m, k, n) = (256usize, 512, 256);
    let macs = (m * k * n) as f64;
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let engine = ParallelGemm::new(&arch);
    let mut gcfg = GemmConfig::paper_table2(8);
    gcfg.ccp = Ccp { mc: 128, nc: 128, kc: 512 };
    // Model cycles of the full problem at this CCP (identical for the
    // sequential and pooled engines — the accounting is engine-free).
    let engine_model_cycles = {
        let mut c = MatI32::zeros(m, n);
        engine.run(&gcfg, &a, &b, &mut c).unwrap().0.total
    };

    let r = bench("naive_gemm/256x512x256", &cfg, || {
        let mut c = MatI32::zeros(m, n);
        naive_gemm(&a, &b, &mut c);
        black_box(c)
    });
    let naive_t = r.per_iter.median;
    println!("{}   {:.2} GMAC/s", r.human(), r.throughput(macs) / 1e9);

    let r = bench("ikj_gemm/256x512x256", &cfg, || {
        let mut c = MatI32::zeros(m, n);
        ikj_gemm(&a, &b, &mut c);
        black_box(c)
    });
    println!("{}   {:.2} GMAC/s", r.human(), r.throughput(macs) / 1e9);

    let r = bench("blocked_engine/256x512x256", &cfg, || {
        let mut c = MatI32::zeros(m, n);
        engine.run(&gcfg, &a, &b, &mut c).unwrap();
        black_box(c)
    });
    println!(
        "{}   {:.2} GMAC/s  ({:.1}× vs naive)  [model: {engine_model_cycles} AIE cycles]",
        r.human(),
        r.throughput(macs) / 1e9,
        naive_t / r.per_iter.median
    );

    // 4. The same engine on the work-stealing host pool: bit-identical
    // results and cycles, only the wall column moves.
    let pooled = ParallelGemm::new(&arch).with_pool(Arc::new(ThreadPool::from_env()));
    let seq_t = r.per_iter.median;
    let r = bench("pooled_engine/256x512x256", &cfg, || {
        let mut c = MatI32::zeros(m, n);
        let (cy, _) = pooled.run(&gcfg, &a, &b, &mut c).unwrap();
        assert_eq!(cy.total, engine_model_cycles, "pooled cycles must match sequential");
        black_box(c)
    });
    println!(
        "{}   {:.2} GMAC/s  ({:.1}× vs sequential engine)  [model: {engine_model_cycles} AIE \
         cycles — unchanged]",
        r.human(),
        r.throughput(macs) / 1e9,
        seq_t / r.per_iter.median
    );
}
