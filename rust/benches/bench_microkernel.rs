//! Bench: **host micro-kernel performance** — the perf-pass harness for
//! the Rust numeric hot path (EXPERIMENTS.md §Perf).
//!
//! Measures the packed 8×8 micro-kernel, the packing routines, and the
//! full engines against the naive and ikj baselines.
//!
//! ```bash
//! cargo bench --bench bench_microkernel
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::baseline::{ikj_gemm, naive_gemm};
use versal_gemm::gemm::{
    pack_a, pack_b, Ccp, GemmConfig, MatI32, MatU8, MicroKernel, ParallelGemm, MR, NR,
};
use versal_gemm::util::benchkit::{bench, black_box, BenchCfg};
use versal_gemm::util::Pcg32;

fn main() {
    let cfg = BenchCfg::from_env();
    let mut rng = Pcg32::new(0xBE);

    // 1. The micro-kernel itself: 8×8×2048 (the paper's kc).
    let kc = 2048;
    let a = MatU8::random(MR, kc, &mut rng);
    let b = MatU8::random(kc, NR, &mut rng);
    let pa = pack_a(&a, 0, 0, MR, kc);
    let pb = pack_b(&b, 0, 0, kc, NR);
    let r = bench("microkernel/8x8xkc2048", &cfg, || {
        let mut cr = [0i32; MR * NR];
        MicroKernel.run(kc, pa.panel(0), pb.panel(0), &mut cr);
        black_box(cr)
    });
    let macs = (MR * NR * kc) as f64;
    println!("{}   {:.2} GMAC/s", r.human(), r.throughput(macs) / 1e9);

    // 2. Packing routines.
    let big = MatU8::random(256, 2048, &mut rng);
    let r = bench("pack_a/256x2048", &cfg, || black_box(pack_a(&big, 0, 0, 256, 2048)));
    println!("{}   {:.2} GB/s", r.human(), r.throughput(256.0 * 2048.0) / 1e9);
    let bigb = MatU8::random(2048, 256, &mut rng);
    let r = bench("pack_b/2048x256", &cfg, || black_box(pack_b(&bigb, 0, 0, 2048, 256)));
    println!("{}   {:.2} GB/s", r.human(), r.throughput(2048.0 * 256.0) / 1e9);

    // 3. Full engines on a mid-size problem, vs baselines.
    let (m, k, n) = (256usize, 512, 256);
    let macs = (m * k * n) as f64;
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut gcfg = GemmConfig::paper_table2(8);
    gcfg.ccp = Ccp { mc: 128, nc: 128, kc: 512 };

    let r = bench("naive_gemm/256x512x256", &cfg, || {
        let mut c = MatI32::zeros(m, n);
        naive_gemm(&a, &b, &mut c);
        black_box(c)
    });
    let naive_t = r.per_iter.median;
    println!("{}   {:.2} GMAC/s", r.human(), r.throughput(macs) / 1e9);

    let r = bench("ikj_gemm/256x512x256", &cfg, || {
        let mut c = MatI32::zeros(m, n);
        ikj_gemm(&a, &b, &mut c);
        black_box(c)
    });
    println!("{}   {:.2} GMAC/s", r.human(), r.throughput(macs) / 1e9);

    let r = bench("blocked_engine/256x512x256", &cfg, || {
        let mut c = MatI32::zeros(m, n);
        engine.run(&gcfg, &a, &b, &mut c).unwrap();
        black_box(c)
    });
    println!(
        "{}   {:.2} GMAC/s  ({:.1}× vs naive)",
        r.human(),
        r.throughput(macs) / 1e9,
        naive_t / r.per_iter.median
    );
}
