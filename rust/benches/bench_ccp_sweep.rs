//! Bench: **CCP sweep** validating the §4.3 derivation.
//!
//! Sweeps (mc, nc, kc) over feasible/infeasible combinations, reporting
//! simulated throughput and the capacity boundaries — the quantitative
//! backing for "kc ≤ 3750, mc ≈ 4500, nc ≈ 1200".
//!
//! ```bash
//! cargo bench --bench bench_ccp_sweep
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::{Ccp, GemmConfig, ParallelGemm};
use versal_gemm::util::tabulate::{Align, Table};

fn main() {
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let derived = Ccp::derive(&arch, 1);
    println!("=== §4.3 CCP derivation ===\n");
    println!("derived:  {derived}   (paper: kc ≤ 3750, mc ≈ 4500, nc ≈ 1200)\n");

    // Feasibility boundary along each axis.
    println!("capacity boundaries (first infeasible value per axis):");
    let mut kc = 16;
    while (Ccp { mc: 256, nc: 256, kc: kc + 16 }).check(&arch, 1).is_ok() {
        kc += 16;
    }
    println!("  kc max (local memory) : {kc}  — paper bound 3750");
    let mut mc = 8;
    while (Ccp { mc: mc + 8, nc: 256, kc: derived.kc }).check(&arch, 1).is_ok() {
        mc += 8;
    }
    println!("  mc max (Ultra RAM)    : {mc}  — paper ≈4500 at kc=3750");
    let mut nc = 8;
    while (Ccp { mc: 256, nc: nc + 8, kc: derived.kc }).check(&arch, 1).is_ok() {
        nc += 8;
    }
    println!("  nc max (Block RAM)    : {nc}  — paper ≈1200 at kc=3750\n");

    // Throughput sweep on a fixed large problem, 8 tiles.
    println!("=== throughput vs CCP on (m, n, k) = (512, 512, 4096), 8 tiles ===\n");
    let (m, n, k) = (512usize, 512usize, 4096usize);
    let macs = (m * n * k) as u64;
    let mut t = Table::new(&["mc", "nc", "kc", "cycles", "MACs/cycle", "note"]).align(5, Align::Left);
    let mut best: Option<(u64, Ccp)> = None;
    for &mc in &[64usize, 128, 256, 512] {
        for &nc in &[64usize, 128, 256, 512] {
            for &kc in &[512usize, 1024, 2048, 3744] {
                let ccp = Ccp { mc, nc, kc };
                if ccp.check(&arch, 1).is_err() {
                    continue;
                }
                let mut cfg = GemmConfig::paper_table2(8);
                cfg.ccp = ccp;
                // Pure schedule (no numerics) — sweeps stay fast.
                let blocks_m = m.div_ceil(mc) as u64;
                let blocks_n = n.div_ceil(nc) as u64;
                let blocks_k = k.div_ceil(kc) as u64;
                let sched = engine.block_schedule(&cfg, nc / 8, mc / 8, kc, (kc * 8) as u64);
                let total = sched.total * blocks_m * blocks_n * blocks_k;
                if best.as_ref().map(|(b, _)| total < *b).unwrap_or(true) {
                    best = Some((total, ccp));
                }
                if mc == nc && (kc == 2048 || kc == 3744) {
                    t.row(&[
                        mc.to_string(),
                        nc.to_string(),
                        kc.to_string(),
                        total.to_string(),
                        format!("{:.1}", macs as f64 / total as f64),
                        String::new(),
                    ]);
                }
            }
        }
    }
    let (bcycles, bccp) = best.unwrap();
    t.row(&[
        bccp.mc.to_string(),
        bccp.nc.to_string(),
        bccp.kc.to_string(),
        bcycles.to_string(),
        format!("{:.1}", macs as f64 / bcycles as f64),
        "best of sweep".to_string(),
    ]);
    println!("{}", t.to_text());
    println!(
        "best CCP of the sweep: {bccp} — large kc and blocks sized to the \
         FPGA RAMs, as §4.3 prescribes"
    );
}
