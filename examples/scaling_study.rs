//! Strong-scaling study: regenerate Table 2 of the paper and the §5.4
//! efficiency analysis, side by side with the published numbers.
//!
//! ```bash
//! cargo run --release --example scaling_study [-- 1,2,4,8,16,32,64,128]
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::ParallelGemm;
use versal_gemm::report;

fn main() {
    let tiles: Vec<usize> = std::env::args()
        .nth(1)
        .map(|s| s.split(',').map(|p| p.trim().parse().expect("tile count")).collect())
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16, 32]);

    let arch = vc1902();
    println!(
        "Table 2 — strong scaling of the parallel GEMM, fixed problem \
         (m, n, k) = (256, 256, 2048):\n"
    );
    println!("{}", report::table2(&arch, &tiles).to_text());

    // §5.4: parallel efficiency from 1 tile to the largest count.
    let g = ParallelGemm::new(&arch);
    let r1 = g.table2_row(1);
    let last = *tiles.last().unwrap();
    let rn = g.table2_row(last);
    let perf_drop = (1.0 - rn.perf_per_tile / r1.perf_per_tile) * 100.0;
    let speedup = r1.total_cycles as f64 / rn.total_cycles as f64;
    println!("§5.4 scalability: per-tile performance drops {perf_drop:.1}% from 1 → {last} tiles");
    println!("                  (paper: 5.7% from 1 → 32); wall-cycle speedup {speedup:.1}×");

    // §5.3: the communication-bound analysis.
    let tile = versal_gemm::sim::AieTileModel::new(&arch);
    println!("\n§5.3 analysis:");
    println!(
        "  naive estimate (no overlap credit): {:.1} MACs/cycle",
        tile.naive_macs_per_cycle_estimate()
    );
    println!("  measured single-tile rate: {:.1} MACs/cycle", r1.perf_per_tile);
    println!(
        "  compute-to-communication ratio: {:.0} MACs per Ar byte — \
         memory-bound on the Ultra RAM stream (peak is {} MACs/cycle)",
        tile.macs_per_ar_byte(),
        arch.peak_macs_per_cycle()
    );
}
