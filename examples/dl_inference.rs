//! END-TO-END driver: serve a quantised MLP classifier through the full
//! three-layer stack and report latency/throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example dl_inference -- \
//!     --requests 512 --rate 2000 --workers 2 --tiles 8
//! ```
//!
//! The pipeline exercised per request:
//!
//!   client ► Coordinator (router → DynamicBatcher → worker pool)  [L3]
//!          ► PJRT artifact `mlp_u8_b8.hlo.txt` — the quantised MLP
//!            whose every matmul is the Pallas 8×8 u8 micro-kernel  [L2+L1]
//!          ► response with logits + class
//!
//! alongside a *simulated Versal cost*: the same layer GEMMs scheduled on
//! the calibrated platform model, so the report shows both host latency
//! (CPU, PJRT) and the projected accelerator cycles.
//!
//! Falls back to the pure-Rust backend (identical semantics, Rust GEMM
//! engine) when artifacts are missing, so the example always runs.

use std::time::{Duration, Instant};
use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    Backend, BatcherConfig, Coordinator, CoordinatorConfig, RustGemmBackend,
};
use versal_gemm::dl::{model_trace, MlpSpec, ModelKind};
use versal_gemm::gemm::{GemmConfig, ParallelGemm};
use versal_gemm::runtime::{ArtifactRegistry, Engine};
use versal_gemm::util::cli::Args;
use versal_gemm::util::Pcg32;

/// Backend that runs batches on the PJRT MLP artifact (L1/L2 numerics)
/// and prices them on the simulated Versal platform (L3 cost model).
struct PjrtBackend {
    engine: Engine,
    arch: versal_gemm::VersalArch,
    tiles: usize,
}

impl Backend for PjrtBackend {
    fn in_dim(&self) -> usize {
        784
    }
    fn n_classes(&self) -> usize {
        10
    }
    fn infer_batch(&mut self, batch: usize, x: &[f32]) -> anyhow::Result<(Vec<f32>, u64)> {
        // The artifact bakes batch=8: pad smaller batches.
        let baked = 8;
        anyhow::ensure!(batch <= baked, "batch {batch} exceeds artifact batch {baked}");
        let mut padded = vec![0.0f32; baked * 784];
        padded[..batch * 784].copy_from_slice(&x[..batch * 784]);
        let logits = self.engine.mlp_forward(baked, &padded)?;

        // Simulated Versal cycles for this batch's three layer GEMMs.
        let engine = ParallelGemm::new(&self.arch);
        let mut cfg = GemmConfig::paper_table2(self.tiles);
        cfg.ccp = versal_gemm::gemm::Ccp { mc: 256, nc: 256, kc: 1024 };
        let mut cycles = 0u64;
        for shape in model_trace(ModelKind::MlpClassifier { batch }) {
            let panels_b = shape.n.div_ceil(8);
            let panels_a = shape.m.div_ceil(8);
            let kc_eff = shape.k.min(cfg.ccp.kc);
            let br_bytes = (kc_eff * 8) as u64;
            cycles += engine.block_schedule(&cfg, panels_b, panels_a, kc_eff, br_bytes).total;
        }
        Ok((logits[..batch * 10].to_vec(), cycles))
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::default()
        .opt("requests")
        .opt("rate")
        .opt("workers")
        .opt("tiles")
        .opt("batch")
        .flag("rust-backend")
        .parse(&std::env::args().skip(1).collect::<Vec<_>>())
        .map_err(anyhow::Error::msg)?;
    let requests: usize = args.get_num("requests", 256).map_err(anyhow::Error::msg)?;
    let rate: f64 = args.get_num("rate", 2000.0).map_err(anyhow::Error::msg)?;
    let workers: usize = args.get_num("workers", 2).map_err(anyhow::Error::msg)?;
    let tiles: usize = args.get_num("tiles", 8).map_err(anyhow::Error::msg)?;
    let batch: usize = args.get_num("batch", 8).map_err(anyhow::Error::msg)?;

    let have_artifacts =
        !args.has("rust-backend") && ArtifactRegistry::default_location().missing().is_empty();
    println!(
        "=== dl_inference: quantised-MLP serving (backend: {}) ===",
        if have_artifacts { "PJRT artifacts (Pallas micro-kernel)" } else { "Rust GEMM engine" }
    );

    let arch = vc1902();
    let coordinator = Coordinator::start(
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: batch,
                max_wait: Duration::from_millis(2),
                queue_cap: 16384,
            },
            n_workers: workers,
            in_dim: 784,
        },
        {
            let arch = arch.clone();
            move |_| -> Box<dyn Backend> {
                if have_artifacts {
                    Box::new(PjrtBackend {
                        engine: Engine::default_location().expect("PJRT engine"),
                        arch: arch.clone(),
                        tiles,
                    })
                } else {
                    Box::new(RustGemmBackend::new(
                        arch.clone(),
                        MlpSpec::default_classifier(),
                        2024,
                        tiles,
                    ))
                }
            }
        },
    );

    // Warmup: one request per worker forces artifact compilation in every
    // worker thread before the timed window (AOT property: compile once,
    // then the request path is execution-only).
    let warm = Instant::now();
    let warm_rxs: Vec<_> = (0..workers.max(1) * batch)
        .map(|_| coordinator.submit(vec![0.0; 784]).expect("warmup submit"))
        .collect();
    coordinator.flush();
    for rx in warm_rxs {
        let _ = rx.recv();
    }
    println!("warmup (compile + first batches): {:.2?}", warm.elapsed());

    // Synthetic MNIST-like workload with Poisson arrivals.
    let mut rng = Pcg32::new(0xD1);
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for _ in 0..requests {
        let x: Vec<f32> = (0..784).map(|_| rng.f64() as f32).collect();
        pending.push(coordinator.submit(x).map_err(|e| anyhow::anyhow!(e.to_string()))?);
        std::thread::sleep(Duration::from_secs_f64(rng.exp(rate)));
    }
    coordinator.flush();

    // Client-side stats over the timed window only (the coordinator's
    // internal metrics also include the warmup batches).
    let mut class_histogram = [0usize; 10];
    let mut latencies_us: Vec<f64> = Vec::new();
    let mut batch_sizes = 0usize;
    let mut sim_cycles = 0.0f64;
    let mut ok = 0usize;
    for rx in pending {
        if let Ok(resp) = rx.recv() {
            class_histogram[resp.predicted_class] += 1;
            latencies_us.push(resp.latency.as_secs_f64() * 1e6);
            batch_sizes += resp.batch_size;
            sim_cycles += resp.simulated_cycles as f64;
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    let metrics = coordinator.shutdown();

    println!("completed {ok}/{requests} requests in {wall:.2?}");
    println!("throughput: {:.0} req/s (offered rate {rate} req/s)", ok as f64 / wall.as_secs_f64());
    if !latencies_us.is_empty() {
        let s = versal_gemm::util::Summary::of(&latencies_us);
        println!(
            "latency µs: mean {:.0}  p50 {:.0}  p95 {:.0}  p99 {:.0}  max {:.0}",
            s.mean, s.median, s.p95, s.p99, s.max
        );
        println!(
            "batching: mean batch {:.2}; simulated Versal cycles/req {:.0} \
             (≈{:.3} ms/batch at 1 GHz AIE clock)",
            batch_sizes as f64 / ok as f64,
            sim_cycles / ok as f64,
            sim_cycles / ok as f64 / 1e6
        );
    }
    println!("class histogram: {class_histogram:?}");
    println!(
        "(coordinator lifetime: {} completions incl. warmup, {} rejected)",
        metrics.completed(),
        metrics.rejected()
    );
    Ok(())
}
