//! Quickstart: run one parallel GEMM on the simulated Versal ACAP.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the core public API: pick an architecture, derive CCPs,
//! run the paper's parallel design, inspect the cycle breakdown, and
//! verify numerics against the naive oracle.

use versal_gemm::arch::vc1902;
use versal_gemm::gemm::baseline::naive_gemm;
use versal_gemm::gemm::{Ccp, GemmConfig, MatI32, MatU8, ParallelGemm};
use versal_gemm::util::Pcg32;

fn main() -> anyhow::Result<()> {
    // 1. The platform: an AMD Versal VC1902 (Table 1 of the paper).
    let arch = vc1902();
    println!("{}\n", arch.table1().to_text());

    // 2. Cache configuration parameters, derived from the capacities
    //    exactly as §4.3 does (kc ≤ 3750, mc ≈ 4500, nc ≈ 1200).
    let derived = Ccp::derive_aligned(&arch, 1);
    println!("derived CCPs: {derived}");

    // 3. The paper's experimental problem on 8 AIE tiles.
    let cfg = GemmConfig::paper_table2(8);
    let (m, n, k) = (256, 256, 2048);
    let mut rng = Pcg32::new(42);
    let a = MatU8::random(m, k, &mut rng);
    let b = MatU8::random(k, n, &mut rng);
    let mut c = MatI32::zeros(m, n);

    let engine = ParallelGemm::new(&arch);
    let (cycles, stats) = engine.run(&cfg, &a, &b, &mut c)?;

    // 4. Verify the numerics (u8·u8→i32, exact).
    let mut want = MatI32::zeros(m, n);
    naive_gemm(&a, &b, &mut want);
    assert_eq!(c.max_abs_diff(&want), 0, "exact integer GEMM");
    println!("numerics: EXACT match vs naive reference");

    // 5. Inspect the simulated execution.
    let macs = (m * n * k) as u64;
    println!("\nsimulated execution on {} tiles, {}:", cfg.tiles, cfg.ccp);
    println!("  total cycles      : {}", cycles.total);
    println!("  Br copies         : {} cycles", cycles.br_copy);
    println!("  Ar streaming      : {} cycles", cycles.ar_stream);
    println!("  arithmetic        : {} cycles", cycles.arithmetic);
    println!("  Cr GMIO           : {} cycles", cycles.copy_cr);
    println!("  orchestration     : {} cycles", cycles.orchestration);
    println!("  throughput        : {:.1} MACs/cycle ({:.1}/tile)",
        cycles.macs_per_cycle(macs), cycles.macs_per_cycle(macs) / cfg.tiles as f64);
    for s in stats.iter().take(3) {
        println!("  tile {}: {} kernels, {} Br copies", s.tile, s.kernels, s.br_copies);
    }
    println!("  ... (overlap won: serial sum {} vs wall {})", cycles.serial_sum(), cycles.total);
    Ok(())
}
