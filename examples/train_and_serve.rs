//! Train → quantise → serve: the full deployment story on a real (small)
//! learned task, with accuracy accounted at every step.
//!
//! ```bash
//! cargo run --release --example train_and_serve
//! ```
//!
//! 1. Generate a synthetic 4-class gaussian-blob dataset (train + test).
//! 2. Train a float MLP with SGD on the host; log the loss curve.
//! 3. Quantise the trained weights to u8 (the paper's inference dtype).
//! 4. Serve the *test set* through the coordinator, every MAC running on
//!    the simulated Versal parallel GEMM engine.
//! 5. Report float vs quantised-served accuracy, latency, throughput and
//!    simulated AIE cycles.

use std::time::{Duration, Instant};
use versal_gemm::arch::vc1902;
use versal_gemm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, RustGemmBackend,
};
use versal_gemm::dl::train::{Dataset, FloatMlp};
use versal_gemm::dl::MlpSpec;

fn main() {
    let dim = 32;
    let classes = 4;
    let spec = MlpSpec { dims: vec![dim, 48, classes] };

    // 1. Data.
    let train = Dataset::gaussian_blobs_split(800, dim, classes, 0.55, 42, 1);
    let test = Dataset::gaussian_blobs_split(400, dim, classes, 0.55, 42, 2);
    println!("dataset: {} train / {} test, {dim}-d, {classes} classes", train.n, test.n);

    // 2. Train.
    let mut model = FloatMlp::random(spec.clone(), 7);
    let t0 = Instant::now();
    let curve = model.train(&train, 15, 0.05, 1);
    println!("trained {} params in {:.2?}; loss curve:", spec.n_params(), t0.elapsed());
    for (e, l) in curve.iter().enumerate() {
        if e % 3 == 0 || e + 1 == curve.len() {
            println!("  epoch {:2}: loss {:.4}", e + 1, l);
        }
    }
    let float_acc = model.accuracy(&test);
    println!("float test accuracy: {:.1}%", float_acc * 100.0);

    // 3. Quantise.
    let qmodel = model.quantize();

    // 4. Serve the test set through the coordinator.
    let arch = vc1902();
    let qm = qmodel.clone();
    let coordinator = Coordinator::start(
        CoordinatorConfig {
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(1),
                queue_cap: 4096,
            },
            n_workers: 2,
            in_dim: dim,
        },
        move |_| Box::new(RustGemmBackend::with_mlp(vc1902(), qm.clone(), 8)),
    );
    let _ = &arch;

    let t1 = Instant::now();
    let rxs: Vec<_> = (0..test.n)
        .map(|i| coordinator.submit(test.sample(i).0.to_vec()).expect("submit"))
        .collect();
    coordinator.flush();
    let mut ok = 0usize;
    let mut sim_cycles = 0u64;
    let mut latencies: Vec<f64> = Vec::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        if resp.predicted_class == test.sample(i).1 {
            ok += 1;
        }
        sim_cycles += resp.simulated_cycles;
        latencies.push(resp.latency.as_secs_f64() * 1e6);
    }
    let wall = t1.elapsed();
    let metrics = coordinator.shutdown();

    // 5. Report.
    let served_acc = ok as f64 / test.n as f64;
    let s = versal_gemm::util::Summary::of(&latencies);
    println!("\nserved {} test samples in {wall:.2?} ({:.0} req/s)", test.n, test.n as f64 / wall.as_secs_f64());
    println!("quantised-served accuracy: {:.1}%  (float: {:.1}%, Δ {:+.1} pts)",
        served_acc * 100.0, float_acc * 100.0, (served_acc - float_acc) * 100.0);
    println!("latency µs: p50 {:.0}  p95 {:.0}  p99 {:.0}", s.median, s.p95, s.p99);
    println!("mean batch {:.2}; simulated Versal cycles total {sim_cycles}", metrics.mean_batch_size());
    assert!(served_acc > 0.9, "served accuracy should stay high");
    assert!(served_acc >= float_acc - 0.05, "quantisation must not crater accuracy");
    println!("\nOK: quantised deployment preserves the learned model.");
}
