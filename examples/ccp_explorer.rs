//! CCP explorer: how the cache configuration parameters of §4.3 react to
//! the architecture, and what they cost on real DL workload shapes.
//!
//! ```bash
//! cargo run --release --example ccp_explorer
//! ```

use versal_gemm::arch::{vc1902, MemLevel};
use versal_gemm::dl::{model_trace, ModelKind};
use versal_gemm::gemm::{Ccp, GemmConfig, ParallelGemm};
use versal_gemm::util::tabulate::{Align, Table};

fn main() {
    let arch = vc1902();

    // 1. The paper's derivation, and how it moves with local memory size.
    println!("§4.3 CCP derivation vs AIE local-memory capacity:\n");
    let mut t = Table::new(&["local memory", "kc", "mc", "nc", "Br bytes", "feasible"]);
    for local_kb in [8u64, 16, 32, 64, 128] {
        let mut a = arch.clone();
        for m in a.mem.iter_mut() {
            if m.level == MemLevel::LocalMemory {
                m.capacity_bytes = local_kb * 1024;
            }
        }
        if local_kb * 1024 <= 2560 {
            continue;
        }
        let ccp = Ccp::derive_aligned(&a, 1);
        let feasible = ccp.check(&a, 1).is_ok();
        t.row(&[
            format!("{local_kb} KB"),
            ccp.kc.to_string(),
            ccp.mc.to_string(),
            ccp.nc.to_string(),
            (ccp.kc * 8).to_string(),
            feasible.to_string(),
        ]);
    }
    println!("{}", t.to_text());
    println!("(VC1902 row: 32 KB — paper quotes kc ≤ 3750, mc ≈ 4500, nc ≈ 1200)\n");

    // 2. Sweep kc on the paper problem: the compute-to-communication
    //    ratio argument of §4.5 made concrete.
    println!("kc sweep on (m, n, k) = (256, 256, 2048), 8 tiles:\n");
    let engine = ParallelGemm::new(&arch);
    let mut t = Table::new(&["kc", "MACs/byte", "block cycles", "MACs/cycle"]);
    for kc in [256usize, 512, 1024, 2048] {
        let ccp = Ccp { mc: 256, nc: 256, kc };
        let mut cfg = GemmConfig::paper_table2(8);
        cfg.ccp = ccp;
        // One (mc, nc, kc) block schedule; k/kc blocks make the problem.
        let blocks = 2048 / kc;
        let sched =
            engine.block_schedule(&cfg, 256 / 8, 256 / 8, kc, (kc * 8) as u64);
        let total = sched.total * blocks as u64;
        let macs = 256u64 * 256 * 2048;
        t.row(&[
            kc.to_string(),
            format!("{:.2}", ccp.compute_to_comm_ratio()),
            total.to_string(),
            format!("{:.1}", macs as f64 / total as f64),
        ]);
    }
    println!("{}", t.to_text());
    println!("(larger kc ⇒ better Cr amortisation — §4.2/§4.5's argument)\n");

    // 3. Real model GEMM shapes: which ones fit a single block?
    println!("DL workload shapes vs the derived CCPs:\n");
    let ccp = Ccp::derive_aligned(&arch, 1);
    let mut t = Table::new(&["layer", "m", "k", "n", "fits one block", "MMACs"])
        .align(0, Align::Left);
    for kind in [ModelKind::Vgg16, ModelKind::BertBase { seq: 128 }] {
        for s in model_trace(kind).into_iter().take(4) {
            t.row(&[
                s.label.clone(),
                s.m.to_string(),
                s.k.to_string(),
                s.n.to_string(),
                (s.m <= ccp.mc && s.k <= ccp.kc && s.n <= ccp.nc).to_string(),
                format!("{:.1}", s.macs() as f64 / 1e6),
            ]);
        }
    }
    println!("{}", t.to_text());
}
