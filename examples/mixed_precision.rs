//! Mixed-precision inference: quantisation error analysis across the
//! GEMM engine — the paper's "adaptive-precision inference" motivation
//! made measurable.
//!
//! ```bash
//! cargo run --release --example mixed_precision
//! ```

use versal_gemm::arch::vc1902;
use versal_gemm::dl::linear::{Activation, QuantLinear};
use versal_gemm::gemm::{GemmConfig, ParallelGemm, Precision, PrecisionPolicy};
use versal_gemm::quant::QTensor;
use versal_gemm::util::tabulate::{Align, Table};
use versal_gemm::util::Pcg32;

fn main() -> anyhow::Result<()> {
    let arch = vc1902();
    let engine = ParallelGemm::new(&arch);
    let mut cfg = GemmConfig::paper_table2(4);
    cfg.ccp = versal_gemm::gemm::Ccp { mc: 128, nc: 128, kc: 256 };

    // 1. Quantisation error of a single tensor across value ranges.
    println!("per-tensor quantisation error (u8, range-fit):\n");
    let mut t = Table::new(&["range", "scale", "max |err|", "err/scale"]);
    let mut rng = Pcg32::new(0xF1);
    for half_range in [0.5f32, 1.0, 4.0, 16.0] {
        let x: Vec<f32> =
            (0..4096).map(|_| (rng.f64() as f32 * 2.0 - 1.0) * half_range).collect();
        let q = QTensor::from_f32(64, 64, &x);
        let err = q.max_error(&x);
        t.row(&[
            format!("±{half_range}"),
            format!("{:.5}", q.params.scale),
            format!("{err:.5}"),
            format!("{:.2}", err / q.params.scale),
        ]);
    }
    println!("{}", t.to_text());
    println!("(error ≤ scale/2 — the affine-quantisation guarantee)\n");

    // 2. End-to-end layer error: quantised GEMM on the simulated Versal
    //    vs the f32 reference, across layer widths.
    println!("quantised linear layer vs f32 reference (batch 16):\n");
    let mut t = Table::new(&["layer", "k", "max |err|", "rel err", "sim cycles"])
        .align(0, Align::Left);
    for (name, k, n) in [("narrow", 64usize, 32usize), ("mid", 256, 128), ("wide", 1024, 256)] {
        let layer = QuantLinear::random(k, n, Activation::None, &mut rng);
        let x: Vec<f32> = (0..16 * k).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
        let mut sim_cycles = 0u64;
        let got = layer.forward(16, &x, |a, b, c| {
            let (cy, _) = engine.run(&cfg, a, b, c).expect("gemm");
            sim_cycles += cy.total;
        });
        let want = layer.forward_f32(16, &x);
        let scale: f32 =
            want.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6);
        let err = got
            .iter()
            .zip(&want)
            .fold(0.0f32, |m, (g, w)| m.max((g - w).abs()));
        t.row(&[
            name.to_string(),
            k.to_string(),
            format!("{err:.4}"),
            format!("{:.3}%", err / scale * 100.0),
            sim_cycles.to_string(),
        ]);
    }
    println!("{}", t.to_text());
    println!(
        "(absolute error grows ~√k with random data; relative error stays \
         small — why u8 inference works, §1/§4.2)\n"
    );

    // 3. The full §4.2 kernel suite on one layer: accuracy vs cycles per
    //    precision, plus what the adaptive tuner would pick.
    println!("one layer (k=512, n=128, batch 16) across the kernel suite:\n");
    let layer = QuantLinear::random(512, 128, Activation::None, &mut rng);
    let x: Vec<f32> = (0..16 * 512).map(|_| rng.f64() as f32 * 2.0 - 1.0).collect();
    let want = layer.forward_f32(16, &x);
    let mut t = Table::new(&["precision", "max |err|", "sim cycles"]).align(0, Align::Left);
    for prec in Precision::ALL {
        let (got, cycles) = layer.forward_prec(16, &x, prec, &arch, &cfg)?;
        let err = got.iter().zip(&want).fold(0.0f32, |m, (g, w)| m.max((g - w).abs()));
        t.row(&[prec.to_string(), format!("{err:.5}"), cycles.to_string()]);
    }
    println!("{}", t.to_text());
    for budget in [0.5f64, 1e-2, 1e-5] {
        let p = layer.resolve_precision(
            &arch,
            &cfg,
            16,
            PrecisionPolicy::Adaptive { max_rel_error: budget },
        );
        println!("  adaptive @ budget {budget:.0e} → {p}");
    }
    println!("(the tuner trades cycles for accuracy: u8 when the budget is loose, bf16 when tight)");
    Ok(())
}
