#!/usr/bin/env bash
# CI gate for the rust/ workspace: tier-1 build + tests, lint, and the
# quick cluster-scaling smoke (the bench asserts its acceptance gates).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo fmt --check (advisory)"
if cargo fmt --version >/dev/null 2>&1; then
    # Advisory until the tree is formatted once (the authoring container
    # ships no rustfmt — see ROADMAP "Open items"); make it a hard gate
    # in the same commit that runs `cargo fmt --all`.
    cargo fmt --all -- --check \
        || echo "    (format drift — advisory until the one-shot cargo fmt commit lands)"
else
    echo "    (rustfmt component not installed; skipping format gate)"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo clippy -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    # missing_docs stays advisory while the long tail of pre-existing
    # public items gains docs. --force-warn (not -A) is required: the
    # crate's own #![warn(missing_docs)] would override a plain -A, and
    # -D warnings would then promote it to a hard error; --force-warn
    # pins the lint at warn level against both.
    cargo clippy --all-targets -- -D warnings --force-warn missing_docs
else
    echo "    (clippy component not installed; skipping lint)"
fi

echo "==> cargo doc --no-deps (rustdoc lints denied)"
RUSTDOCFLAGS="-D rustdoc::broken-intra-doc-links -D rustdoc::invalid-codeblock-attributes" \
    cargo doc --no-deps --quiet

echo "==> cargo test --doc"
cargo test -q --doc

echo "==> bench_cluster_scaling --quick (smoke)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_cluster_scaling -- --quick

echo "==> precision conformance matrix (per-precision, so a failure names the precision)"
for prec in u8 i8 i16 bf16; do
    echo "    -- VERSAL_PRECISION=${prec}"
    VERSAL_PRECISION="${prec}" cargo test -q --test precision_conformance
done

echo "==> bench_mixed_precision --quick (smoke)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_mixed_precision -- --quick

echo "==> bench_serving --quick (smoke: batched+cached beats sequential, hits bit-exact, plan cache lowers once)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_serving -- --quick

echo "==> bench_plan --quick (smoke: plan predicted == executed, streaming == materialized)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_plan -- --quick

echo "==> bench artifacts present (uploaded by the workflow for the BENCH trajectory)"
# cargo runs bench binaries with the package dir (rust/) as cwd, so the
# artifacts land in rust/bench_results — the same paths the workflow
# uploads.
for artifact in BENCH_plan.json BENCH_serving.json; do
    test -s "rust/bench_results/${artifact}" \
        || { echo "missing bench artifact rust/bench_results/${artifact}" >&2; exit 1; }
    echo "    rust/bench_results/${artifact}: $(wc -c < "rust/bench_results/${artifact}") bytes"
done

echo "CI checks passed."
