#!/usr/bin/env bash
# CI gate for the rust/ workspace: tier-1 build + tests, lint, and the
# quick cluster-scaling smoke (the bench asserts its acceptance gates).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo fmt --check (advisory)"
# Re-probed while landing the pack-arena PR: the authoring container
# still ships no rustfmt, so the gate stays advisory (see ROADMAP
# "Open items"); make it a hard gate in the same commit that runs
# `cargo fmt --all`.
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check \
        || echo "    (format drift — advisory until the one-shot cargo fmt commit lands)"
else
    echo "    (rustfmt component not installed; skipping format gate)"
fi

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> overload invariant battery (tests/serving_overload.rs, named so a failure is attributable)"
# Also covered by the blanket `cargo test -q` above; the dedicated run
# keeps the overload invariants visible as their own gate in CI logs.
cargo test -q --test serving_overload

echo "==> cross-engine parity battery (tests/engine_parity.rs across the PALLAS_POOL_SIZE x PALLAS_PACK_PARALLEL matrix)"
# The threads engine must be bit-identical to the sequential walk at
# every pool width, with packing serial and slice-parallel; each leg
# pins one (width, pack mode) so a failure names it.
for ps in 1 2 8; do
    for pp in 0 1; do
        echo "    -- PALLAS_POOL_SIZE=${ps} PALLAS_PACK_PARALLEL=${pp}"
        PALLAS_POOL_SIZE="${ps}" PALLAS_PACK_PARALLEL="${pp}" \
            cargo test -q --test engine_parity
    done
done

echo "==> fault-tolerance chaos battery (tests/fault_tolerance.rs, named so a failure is attributable)"
# Seeded storms replay byte-identically, the conservation ledger never
# leaks under faults, retries respect deadlines and budgets, and
# quarantine-and-replan is bit-exact vs the healthy pool.
cargo test -q --test fault_tolerance
# One leg under the threads engine: fault handling must stay
# deterministic when the GEMM numerics run on a host pool with
# slice-parallel packing.
echo "    -- PALLAS_POOL_SIZE=2 PALLAS_PACK_PARALLEL=1"
PALLAS_POOL_SIZE=2 PALLAS_PACK_PARALLEL=1 cargo test -q --test fault_tolerance

echo "==> pack-arena allocation regression (tests/serving_alloc.rs, named so a failure is attributable)"
# Warm plan walks must allocate zero bytes and warm serving ticks must
# be allocation-flat; the counting global allocator pins both.
cargo test -q --test serving_alloc

echo "==> cargo clippy -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    # missing_docs stays advisory while the long tail of pre-existing
    # public items gains docs. --force-warn (not -A) is required: the
    # crate's own #![warn(missing_docs)] would override a plain -A, and
    # -D warnings would then promote it to a hard error; --force-warn
    # pins the lint at warn level against both.
    cargo clippy --all-targets -- -D warnings --force-warn missing_docs
else
    echo "    (clippy component not installed; skipping lint)"
fi

echo "==> cargo doc --no-deps (rustdoc lints denied)"
RUSTDOCFLAGS="-D rustdoc::broken-intra-doc-links -D rustdoc::invalid-codeblock-attributes" \
    cargo doc --no-deps --quiet

echo "==> cargo test --doc"
cargo test -q --doc

echo "==> bench_cluster_scaling --quick (smoke)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_cluster_scaling -- --quick

echo "==> precision conformance matrix (per-precision, so a failure names the precision)"
for prec in u8 i8 i16 bf16; do
    echo "    -- VERSAL_PRECISION=${prec}"
    VERSAL_PRECISION="${prec}" cargo test -q --test precision_conformance
done

echo "==> bench_mixed_precision --quick (smoke)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_mixed_precision -- --quick

echo "==> bench_serving --quick (smoke: batched+cached beats sequential, hits bit-exact, plan cache lowers once, goodput knee past overload)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_serving -- --quick

echo "==> bench_plan --quick (smoke: plan predicted == executed, streaming == materialized)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_plan -- --quick

echo "==> bench_faults --quick (smoke: empty plan free, device-loss goodput floor, storm ledger, seeded determinism)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_faults -- --quick

echo "==> serve --trace-out (quick Chrome trace artifact)"
# The serving trace rides along with the BENCH artifacts: a small
# deterministic replay exported as Chrome trace-event JSON. The build
# step above produced the release binary; artifacts share the bench dir.
mkdir -p rust/bench_results
target/release/versal-gemm serve --requests 32 --batch 4 --tiles 2 --rate 100000 \
    --slo-ms 200 --trace-out rust/bench_results/TRACE_serving.json >/dev/null

echo "==> validate Chrome trace JSON (well-formed, all phases present)"
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PY'
import json

with open("rust/bench_results/TRACE_serving.json") as f:
    doc = json.load(f)
assert doc.get("displayTimeUnit") == "ns", "unexpected displayTimeUnit"
events = doc["traceEvents"]
assert isinstance(events, list) and events, "traceEvents must be a non-empty list"
phases = {e.get("ph") for e in events}
for ph in ("M", "X", "i", "C"):
    assert ph in phases, f"missing phase {ph!r} in trace"
for e in events:
    assert isinstance(e.get("name"), str) and isinstance(e.get("pid"), int), e
print(f"    TRACE_serving.json: {len(events)} events, phases {sorted(phases)}")
PY
else
    # The structural checks also run natively in tests/trace_conformance.rs;
    # python3 just cross-validates with an independent JSON parser.
    echo "    (python3 unavailable; cross-validation skipped — covered by cargo tests)"
fi

echo "==> bench-trend vs previous artifacts (blocking: >5% cycle growth fails)"
# When a previous run's artifacts are present (the workflow downloads
# them best-effort), diff them metric by metric; >5% growth on any
# *_cycles metric fails the gate. Artifacts carry a top-level "schema"
# tag — when it changes (metric rename / resize), bench-trend resets
# the baseline instead of failing, so schema migrations stay one-commit.
for artifact in BENCH_plan.json BENCH_serving.json BENCH_faults.json; do
    prev="bench_baseline/${artifact}"
    if [ -s "${prev}" ]; then
        target/release/versal-gemm bench-trend --fail-on-regress \
            "${prev}" "rust/bench_results/${artifact}"
    else
        echo "    (no previous ${artifact} at ${prev}; skipping trend diff)"
    fi
done

echo "==> bench artifacts present (uploaded by the workflow for the BENCH trajectory)"
# cargo runs bench binaries with the package dir (rust/) as cwd, so the
# artifacts land in rust/bench_results — the same paths the workflow
# uploads.
for artifact in BENCH_plan.json BENCH_serving.json BENCH_faults.json TRACE_serving.json; do
    test -s "rust/bench_results/${artifact}" \
        || { echo "missing bench artifact rust/bench_results/${artifact}" >&2; exit 1; }
    echo "    rust/bench_results/${artifact}: $(wc -c < "rust/bench_results/${artifact}") bytes"
done

echo "==> wall-time columns present in bench artifacts (wall_ns next to the cycle metrics)"
# The wall-time fields are first-class in the uploaded JSON but named
# so bench-trend's cycle-domain gate never fires on machine noise.
for artifact in BENCH_plan.json BENCH_serving.json; do
    grep -q '"wall_ns"' "rust/bench_results/${artifact}" \
        || { echo "missing wall_ns field in rust/bench_results/${artifact}" >&2; exit 1; }
    echo "    rust/bench_results/${artifact}: wall_ns present"
done
grep -q '"goodput_sweep"' rust/bench_results/BENCH_serving.json \
    || { echo "BENCH_serving.json must carry the goodput_sweep block in quick mode too" >&2; exit 1; }
grep -q '"pack_wall_ns"' rust/bench_results/BENCH_plan.json \
    || { echo "BENCH_plan.json must carry per-case pack_wall_ns (schema plan-v3)" >&2; exit 1; }
grep -q '"fanout"' rust/bench_results/BENCH_serving.json \
    || { echo "BENCH_serving.json must carry the fanout block (schema serving-v4)" >&2; exit 1; }
grep -q '"faults-v1"' rust/bench_results/BENCH_faults.json \
    || { echo "BENCH_faults.json must carry the faults-v1 schema tag" >&2; exit 1; }
grep -q '"goodput_after_fault"' rust/bench_results/BENCH_faults.json \
    || { echo "BENCH_faults.json must carry the goodput_after_fault gate value" >&2; exit 1; }

echo "CI checks passed."
