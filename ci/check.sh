#!/usr/bin/env bash
# CI gate for the rust/ workspace: tier-1 build + tests, lint, and the
# quick cluster-scaling smoke (the bench asserts its acceptance gates).
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo clippy -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "    (clippy component not installed; skipping lint)"
fi

echo "==> bench_cluster_scaling --quick (smoke)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_cluster_scaling -- --quick

echo "==> precision conformance matrix (per-precision, so a failure names the precision)"
for prec in u8 i8 i16 bf16; do
    echo "    -- VERSAL_PRECISION=${prec}"
    VERSAL_PRECISION="${prec}" cargo test -q --test precision_conformance
done

echo "==> bench_mixed_precision --quick (smoke)"
VERSAL_BENCH_FAST=1 cargo bench --bench bench_mixed_precision -- --quick

echo "CI checks passed."
